//! TCP serving front-end (S9): JSON-lines over std::net, one handler
//! thread per connection, all inference flowing through the coordinator.
//!
//! Hardening (PR 6): each connection reads with a bounded timeout, so
//! handler threads poll the shutdown flag instead of blocking forever on
//! an idle socket; partially-received lines survive the poll ticks.
//! Malformed input (bad JSON, invalid UTF-8) gets a typed error line
//! with its stable `error_code` rather than an opaque string. Shutdown
//! is graceful: in-flight requests finish and their replies are written
//! before the handlers exit and the listener joins them.

use super::proto::{error_response, ok_response, text_response, Request};
use crate::coordinator::{Coordinator, EnginePath, InferRequest, Payload};
use crate::error::FheError;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long a connection read may block before the handler re-checks
/// the shutdown flag — bounds shutdown latency for idle connections.
const READ_POLL: Duration = Duration::from_millis(250);

/// Per-request response budget when the client sent no `deadline_ms`.
const DEFAULT_INFER_TIMEOUT: Duration = Duration::from_secs(60);

/// Serve until a shutdown request arrives. Returns the bound address
/// through `on_ready` (used by tests/benches binding port 0).
pub fn serve(
    coordinator: Arc<Coordinator>,
    addr: &str,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);
    let stop = Arc::new(AtomicBool::new(false));
    let mut handlers = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let c = Arc::clone(&coordinator);
                let s = Arc::clone(&stop);
                handlers.push(std::thread::spawn(move || handle_conn(stream, c, s)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    // Graceful drain: every handler finishes its in-flight request and
    // writes the reply before exiting (they notice `stop` within
    // READ_POLL once idle).
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, coordinator: Arc<Coordinator>, stop: Arc<AtomicBool>) {
    // The listener is non-blocking; make sure the accepted socket is not
    // (inheritance is platform-dependent) so the read timeout governs.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // One persistent line buffer: a read that times out mid-line keeps
    // the partial bytes here and the next tick appends to them.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {
                let l = std::mem::take(&mut line);
                let l = l.trim();
                if l.is_empty() {
                    continue;
                }
                match handle_line(l, &coordinator, &stop, &mut writer) {
                    LineOutcome::Continue => {}
                    LineOutcome::Close => break,
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle poll tick (partial input, if any, stays in `line`):
                // exit promptly once shutdown begins.
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Not UTF-8: tell the client in-protocol, then drop the
                // connection (the stream offset is unrecoverable).
                let _ = writeln!(
                    writer,
                    "{}",
                    error_response(&FheError::Protocol(
                        "request line is not valid UTF-8".to_string()
                    ))
                );
                break;
            }
            Err(_) => break,
        }
    }
}

enum LineOutcome {
    Continue,
    Close,
}

fn handle_line(
    line: &str,
    coordinator: &Coordinator,
    stop: &AtomicBool,
    writer: &mut TcpStream,
) -> LineOutcome {
    let reply = match Request::parse(line) {
        Err(e) => error_response(&e),
        Ok(Request::Ping) => text_response("pong"),
        Ok(Request::Metrics) => text_response(&coordinator.metrics().summary()),
        Ok(Request::Shutdown) => {
            stop.store(true, Ordering::Relaxed);
            let _ = writeln!(writer, "{}", text_response("shutting down"));
            return LineOutcome::Close;
        }
        Ok(Request::Infer { engine, target, features, rows, cols, deadline_ms }) => {
            let path = match engine.as_str() {
                "quant" => EnginePath::QuantInt(target),
                "pjrt" => EnginePath::Pjrt(target),
                other => {
                    let e = FheError::UnknownEngine(format!("unknown engine '{other}'"));
                    let _ = writeln!(writer, "{}", error_response(&e));
                    return LineOutcome::Continue;
                }
            };
            // The relative wire budget becomes an absolute deadline the
            // scheduler drops on at dequeue and the encrypted executor
            // checks at every PBS level boundary.
            let mut req =
                InferRequest::new(0, path, Payload::Features(features, (rows, cols)));
            let timeout = match deadline_ms {
                Some(ms) => {
                    let budget = Duration::from_millis(ms);
                    req = req.with_deadline(Instant::now() + budget);
                    // Allow the deadline machinery to answer first; the
                    // recv timeout is only the backstop.
                    budget + Duration::from_secs(5)
                }
                None => DEFAULT_INFER_TIMEOUT,
            };
            match coordinator.infer_request_blocking(req, timeout) {
                Ok(resp) => match resp.error {
                    None => ok_response(&resp.output, resp.result_blob, resp.latency_s),
                    Some(e) => error_response(&e),
                },
                Err(e) => error_response(&e),
            }
        }
        Ok(Request::Decode { session, mechanism, stream, blob, prefill, deadline_ms }) => {
            // The decode op implies the engine-key prefix; accept the
            // mechanism with or without it.
            let mechanism = if mechanism.starts_with("decode/") {
                mechanism
            } else {
                format!("decode/{mechanism}")
            };
            let path = EnginePath::Encrypted { session, mechanism };
            // Prefill opens the stream (no cache yet); a step extends it
            // in place. Either way the successor cache lands under
            // `stream`.
            let cache_ref = if prefill { None } else { Some(stream) };
            let mut req = InferRequest::new(0, path, Payload::CiphertextRef(blob))
                .with_cache(cache_ref, Some(stream));
            let timeout = match deadline_ms {
                Some(ms) => {
                    let budget = Duration::from_millis(ms);
                    req = req.with_deadline(Instant::now() + budget);
                    budget + Duration::from_secs(5)
                }
                None => DEFAULT_INFER_TIMEOUT,
            };
            match coordinator.infer_request_blocking(req, timeout) {
                Ok(resp) => match resp.error {
                    None => ok_response(&resp.output, resp.result_blob, resp.latency_s),
                    Some(e) => error_response(&e),
                },
                Err(e) => error_response(&e),
            }
        }
        Ok(Request::ReleaseCache { session, stream }) => {
            if coordinator.release_cache(session, stream) {
                text_response("cache released")
            } else {
                error_response(&FheError::KeyMissing(format!(
                    "no live cache bundle for stream {stream}"
                )))
            }
        }
        Ok(Request::DropSession { session }) => {
            if coordinator.drop_session(session) {
                text_response("session dropped")
            } else {
                error_response(&FheError::KeyMissing(format!("unknown session {session}")))
            }
        }
    };
    if writeln!(writer, "{reply}").is_err() {
        return LineOutcome::Close;
    }
    LineOutcome::Continue
}
