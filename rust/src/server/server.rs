//! TCP serving front-end (S9): JSON-lines over std::net, one handler
//! thread per connection, all inference flowing through the coordinator.

use super::proto::{err_response, ok_response, text_response, Request};
use crate::coordinator::{Coordinator, EnginePath, Payload};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Serve until a shutdown request arrives. Returns the bound address
/// through `on_ready` (used by tests/benches binding port 0).
pub fn serve(
    coordinator: Arc<Coordinator>,
    addr: &str,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);
    let stop = Arc::new(AtomicBool::new(false));
    let mut handlers = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let c = Arc::clone(&coordinator);
                let s = Arc::clone(&stop);
                handlers.push(std::thread::spawn(move || handle_conn(stream, c, s)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, coordinator: Arc<Coordinator>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Request::parse(&line) {
            Err(e) => err_response(&e),
            Ok(Request::Ping) => text_response("pong"),
            Ok(Request::Metrics) => text_response(&coordinator.metrics().summary()),
            Ok(Request::Shutdown) => {
                stop.store(true, Ordering::Relaxed);
                let _ = writeln!(writer, "{}", text_response("shutting down"));
                break;
            }
            Ok(Request::Infer { engine, target, features, rows, cols }) => {
                let path = match engine.as_str() {
                    "quant" => EnginePath::QuantInt(target),
                    "pjrt" => EnginePath::Pjrt(target),
                    other => {
                        let _ = writeln!(
                            writer,
                            "{}",
                            err_response(&format!("unknown engine '{other}'"))
                        );
                        continue;
                    }
                };
                match coordinator.infer_blocking(
                    path,
                    Payload::Features(features, (rows, cols)),
                    Duration::from_secs(60),
                ) {
                    Ok(resp) => match resp.error {
                        None => ok_response(&resp.output, resp.result_blob, resp.latency_s),
                        Some(e) => err_response(&e),
                    },
                    Err(e) => err_response(&e),
                }
            }
        };
        if writeln!(writer, "{reply}").is_err() {
            break;
        }
    }
    let _ = peer;
}
