//! Minimal blocking client for the JSON-lines protocol (tests, benches,
//! and the `inhibitor client` CLI subcommand).

use super::proto::Request;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    fn roundtrip(&mut self, line: &str) -> std::io::Result<Json> {
        writeln!(self.writer, "{line}")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Json::parse(reply.trim())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }

    pub fn ping(&mut self) -> std::io::Result<bool> {
        Ok(self.roundtrip(r#"{"op":"ping"}"#)?.get("ok").and_then(|v| v.as_bool())
            == Some(true))
    }

    pub fn metrics(&mut self) -> std::io::Result<String> {
        Ok(self
            .roundtrip(r#"{"op":"metrics"}"#)?
            .get("text")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string())
    }

    pub fn shutdown(&mut self) -> std::io::Result<()> {
        let _ = self.roundtrip(r#"{"op":"shutdown"}"#)?;
        Ok(())
    }

    /// Run an inference; returns (output, latency reported by the server).
    pub fn infer(
        &mut self,
        engine: &str,
        target: &str,
        features: Vec<f32>,
        rows: usize,
        cols: usize,
    ) -> std::io::Result<Result<(Vec<f32>, f64), String>> {
        let req = Request::Infer {
            engine: engine.into(),
            target: target.into(),
            features,
            rows,
            cols,
        };
        let j = self.roundtrip(&req.to_json_line())?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            let out = j
                .get("output")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
                .unwrap_or_default();
            let lat = j.get("latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            Ok(Ok((out, lat)))
        } else {
            Ok(Err(j
                .get("error")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown error")
                .to_string()))
        }
    }
}
