//! Minimal blocking client for the JSON-lines protocol (tests, benches,
//! and the `inhibitor client` CLI subcommand).

use super::proto::Request;
use crate::error::FheError;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    fn roundtrip(&mut self, line: &str) -> std::io::Result<Json> {
        writeln!(self.writer, "{line}")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Json::parse(reply.trim())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }

    pub fn ping(&mut self) -> std::io::Result<bool> {
        Ok(self.roundtrip(r#"{"op":"ping"}"#)?.get("ok").and_then(|v| v.as_bool())
            == Some(true))
    }

    pub fn metrics(&mut self) -> std::io::Result<String> {
        Ok(self
            .roundtrip(r#"{"op":"metrics"}"#)?
            .get("text")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string())
    }

    pub fn shutdown(&mut self) -> std::io::Result<()> {
        let _ = self.roundtrip(r#"{"op":"shutdown"}"#)?;
        Ok(())
    }

    /// Run an inference; returns (output, latency reported by the server),
    /// or the server's failure rebuilt as a **typed** [`FheError`] from
    /// the wire `error_code` (so callers can branch on
    /// `deadline_exceeded` vs `worker_panic` instead of grepping text).
    pub fn infer(
        &mut self,
        engine: &str,
        target: &str,
        features: Vec<f32>,
        rows: usize,
        cols: usize,
    ) -> std::io::Result<Result<(Vec<f32>, f64), FheError>> {
        self.infer_with_deadline(engine, target, features, rows, cols, None)
    }

    /// [`Self::infer`] with an optional relative deadline budget in
    /// milliseconds, enforced server-side (scheduler dequeue + PBS level
    /// boundaries).
    pub fn infer_with_deadline(
        &mut self,
        engine: &str,
        target: &str,
        features: Vec<f32>,
        rows: usize,
        cols: usize,
        deadline_ms: Option<u64>,
    ) -> std::io::Result<Result<(Vec<f32>, f64), FheError>> {
        let req = Request::Infer {
            engine: engine.into(),
            target: target.into(),
            features,
            rows,
            cols,
            deadline_ms,
        };
        let j = self.roundtrip(&req.to_json_line())?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            let out = j
                .get("output")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
                .unwrap_or_default();
            let lat = j.get("latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            Ok(Ok((out, lat)))
        } else {
            Ok(Err(Self::wire_error(&j)))
        }
    }

    /// One incremental-decode request (PR 7): prefill sends the
    /// registered `[T, D]` grid bundle and opens `stream`; a step sends a
    /// one-row bundle extending it. Returns (result blob id, latency) —
    /// the output row stays encrypted in the session store — or the
    /// server's typed failure.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &mut self,
        session: u64,
        mechanism: &str,
        stream: u64,
        blob: u64,
        prefill: bool,
        deadline_ms: Option<u64>,
    ) -> std::io::Result<Result<(u64, f64), FheError>> {
        let req = Request::Decode {
            session,
            mechanism: mechanism.into(),
            stream,
            blob,
            prefill,
            deadline_ms,
        };
        let j = self.roundtrip(&req.to_json_line())?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            let lat = j.get("latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            match j.get("result_blob").and_then(|v| v.as_i64()) {
                Some(id) if id >= 0 => Ok(Ok((id as u64, lat))),
                _ => Ok(Err(FheError::Protocol(
                    "decode response carried no result_blob".to_string(),
                ))),
            }
        } else {
            Ok(Err(Self::wire_error(&j)))
        }
    }

    /// Drop a decode stream's server-side cache bundle explicitly.
    pub fn release_cache(
        &mut self,
        session: u64,
        stream: u64,
    ) -> std::io::Result<Result<(), FheError>> {
        let req = Request::ReleaseCache { session, stream };
        let j = self.roundtrip(&req.to_json_line())?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(Ok(()))
        } else {
            Ok(Err(Self::wire_error(&j)))
        }
    }

    /// Tear a session down completely on the server: key material,
    /// result blobs, and every decode cache bundle.
    pub fn drop_session(&mut self, session: u64) -> std::io::Result<Result<(), FheError>> {
        let req = Request::DropSession { session };
        let j = self.roundtrip(&req.to_json_line())?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(Ok(()))
        } else {
            Ok(Err(Self::wire_error(&j)))
        }
    }

    /// Rebuild the server's typed failure from the wire fields.
    fn wire_error(j: &Json) -> FheError {
        let msg = j.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error");
        match j.get("error_code").and_then(|v| v.as_str()) {
            Some(code) => FheError::from_code(code, msg),
            // Pre-PR-6 server without error codes: keep the message.
            None => FheError::Internal(msg.to_string()),
        }
    }
}
