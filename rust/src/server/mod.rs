//! TCP serving layer (S9): JSON-lines protocol, server, blocking client.

pub mod client;
pub mod proto;
#[allow(clippy::module_inception)]
pub mod server;

pub use client::Client;
pub use proto::Request;
pub use server::serve;
