//! Wire protocol (S9): JSON-lines over TCP. One request object per line,
//! one response object per line. Kept deliberately simple — the paper's
//! contribution is below this layer — but complete enough to drive the
//! serving benchmark from a separate process.
//!
//! Requests:
//!   {"op":"infer","engine":"quant","mechanism":"inhibitor",
//!    "features":[...],"rows":R,"cols":C}
//!   {"op":"infer","engine":"pjrt","model":"model_inhibitor",
//!    "features":[...],"rows":R,"cols":C}
//!   {"op":"metrics"}   {"op":"ping"}   {"op":"shutdown"}
//!
//! Responses:
//!   {"ok":true,"output":[...],"latency_s":...}  |  {"ok":false,"error":"..."}

use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Metrics,
    Shutdown,
    Infer {
        engine: String,
        target: String,
        features: Vec<f32>,
        rows: usize,
        cols: usize,
    },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        match j.get("op").and_then(|v| v.as_str()) {
            Some("ping") => Ok(Request::Ping),
            Some("metrics") => Ok(Request::Metrics),
            Some("shutdown") => Ok(Request::Shutdown),
            Some("infer") => {
                let engine = j
                    .get("engine")
                    .and_then(|v| v.as_str())
                    .ok_or("missing 'engine'")?
                    .to_string();
                let target = j
                    .get("mechanism")
                    .or_else(|| j.get("model"))
                    .and_then(|v| v.as_str())
                    .ok_or("missing 'mechanism'/'model'")?
                    .to_string();
                let features = j
                    .get("features")
                    .and_then(|v| v.as_arr())
                    .ok_or("missing 'features'")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32).ok_or("non-numeric feature"))
                    .collect::<Result<Vec<_>, _>>()?;
                let rows =
                    j.get("rows").and_then(|v| v.as_i64()).ok_or("missing 'rows'")? as usize;
                let cols =
                    j.get("cols").and_then(|v| v.as_i64()).ok_or("missing 'cols'")? as usize;
                if rows * cols != features.len() {
                    return Err(format!(
                        "features length {} != rows*cols {}",
                        features.len(),
                        rows * cols
                    ));
                }
                Ok(Request::Infer { engine, target, features, rows, cols })
            }
            other => Err(format!("unknown op {other:?}")),
        }
    }

    pub fn to_json_line(&self) -> String {
        match self {
            Request::Ping => r#"{"op":"ping"}"#.to_string(),
            Request::Metrics => r#"{"op":"metrics"}"#.to_string(),
            Request::Shutdown => r#"{"op":"shutdown"}"#.to_string(),
            Request::Infer { engine, target, features, rows, cols } => {
                let key = if engine == "pjrt" { "model" } else { "mechanism" };
                Json::obj(vec![
                    ("op", Json::str("infer")),
                    ("engine", Json::str(engine.clone())),
                    (key, Json::str(target.clone())),
                    (
                        "features",
                        Json::arr(features.iter().map(|&f| Json::num(f as f64)).collect()),
                    ),
                    ("rows", Json::num(*rows as f64)),
                    ("cols", Json::num(*cols as f64)),
                ])
                .to_string()
            }
        }
    }
}

/// Build a success response line.
pub fn ok_response(output: &[f32], latency_s: f64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("output", Json::arr(output.iter().map(|&f| Json::num(f as f64)).collect())),
        ("latency_s", Json::num(latency_s)),
    ])
    .to_string()
}

/// Build an error response line.
pub fn err_response(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).to_string()
}

/// Build a free-form text response (metrics).
pub fn text_response(text: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("text", Json::str(text))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_infer() {
        let req = Request::Infer {
            engine: "quant".into(),
            target: "inhibitor".into(),
            features: vec![1.0, 2.0, 3.0, 4.0],
            rows: 2,
            cols: 2,
        };
        let line = req.to_json_line();
        assert_eq!(Request::parse(&line).unwrap(), req);
    }

    #[test]
    fn parse_control_ops() {
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(Request::parse(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(Request::parse(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"op":"teleport"}"#).is_err());
        assert!(Request::parse(
            r#"{"op":"infer","engine":"quant","mechanism":"x","features":[1],"rows":2,"cols":2}"#
        )
        .is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        for s in [
            ok_response(&[1.0, -2.5], 0.01),
            err_response("boom"),
            text_response("a\nb"),
        ] {
            crate::util::json::Json::parse(&s).unwrap();
        }
    }
}
