//! Wire protocol (S9): JSON-lines over TCP. One request object per line,
//! one response object per line. Kept deliberately simple — the paper's
//! contribution is below this layer — but complete enough to drive the
//! serving benchmark from a separate process.
//!
//! Requests:
//!   {"op":"infer","engine":"quant","mechanism":"inhibitor",
//!    "features":[...],"rows":R,"cols":C[,"deadline_ms":N]}
//!   {"op":"infer","engine":"pjrt","model":"model_inhibitor",
//!    "features":[...],"rows":R,"cols":C}
//!   {"op":"decode","session":S,"mechanism":"inhibitor@h2xL2",
//!    "stream":N,"blob":B,"prefill":true[,"deadline_ms":N]}
//!   {"op":"release_cache","session":S,"stream":N}
//!   {"op":"drop_session","session":S}
//!   {"op":"metrics"}   {"op":"ping"}   {"op":"shutdown"}
//!
//! Responses:
//!   {"ok":true,"output":[...],"latency_s":...}
//!   {"ok":false,"error":"...","error_code":"..."}
//!
//! Error lines carry a **stable machine-readable `error_code`**
//! ([`FheError::code`] — e.g. `"deadline_exceeded"`, `"worker_panic"`)
//! alongside the human-readable message; clients rebuild the typed error
//! with [`FheError::from_code`]. `deadline_ms` is a relative budget the
//! server turns into an absolute deadline at parse time.
//!
//! Encrypted results travel as a typed `"result_blob":<id>` field (the
//! session-store reference), never inside the f32 `output` vector. The
//! in-process coordinator API carries the id as an exact `u64`; this
//! JSON layer encodes it as a number, exact up to 2⁵³ — ids past that
//! (only reachable by deliberately partitioning the id space via
//! `Session::set_next_blob_id`) are refused loudly rather than rounded.

use crate::error::FheError;
use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Metrics,
    Shutdown,
    Infer {
        engine: String,
        target: String,
        features: Vec<f32>,
        rows: usize,
        cols: usize,
        /// Relative deadline budget in milliseconds; the server converts
        /// it to an absolute `Instant` when the request is accepted.
        deadline_ms: Option<u64>,
    },
    /// One incremental-decode request against a session's decode engine
    /// (PR 7). `prefill: true` sends the registered `[T, D]` grid bundle
    /// `blob` and opens stream `stream` (depositing its encrypted
    /// KV-cache server-side); `prefill: false` sends a one-row bundle
    /// that extends the stream's cache by one position. The mechanism may
    /// be given with or without its `decode/` prefix.
    Decode {
        session: u64,
        mechanism: String,
        stream: u64,
        blob: u64,
        prefill: bool,
        deadline_ms: Option<u64>,
    },
    /// Drop a decode stream's server-side cache bundle explicitly.
    ReleaseCache { session: u64, stream: u64 },
    /// Tear a session down completely: key material, result blobs, and
    /// every decode cache bundle (hot, spilled, and sink bytes).
    DropSession { session: u64 },
}

impl Request {
    /// Parse one request line. Unparseable JSON is a [`FheError::Protocol`]
    /// failure; well-formed JSON with bad fields is [`FheError::BadRequest`].
    pub fn parse(line: &str) -> Result<Request, FheError> {
        let j = Json::parse(line)
            .map_err(|e| FheError::Protocol(format!("malformed request line: {e}")))?;
        let bad = |m: &str| FheError::BadRequest(m.to_string());
        match j.get("op").and_then(|v| v.as_str()) {
            Some("ping") => Ok(Request::Ping),
            Some("metrics") => Ok(Request::Metrics),
            Some("shutdown") => Ok(Request::Shutdown),
            Some("infer") => {
                let engine = j
                    .get("engine")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("missing 'engine'"))?
                    .to_string();
                let target = j
                    .get("mechanism")
                    .or_else(|| j.get("model"))
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("missing 'mechanism'/'model'"))?
                    .to_string();
                let features = j
                    .get("features")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| bad("missing 'features'"))?
                    .iter()
                    .map(|v| {
                        v.as_f64().map(|f| f as f32).ok_or_else(|| bad("non-numeric feature"))
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                let rows = j
                    .get("rows")
                    .and_then(|v| v.as_i64())
                    .ok_or_else(|| bad("missing 'rows'"))? as usize;
                let cols = j
                    .get("cols")
                    .and_then(|v| v.as_i64())
                    .ok_or_else(|| bad("missing 'cols'"))? as usize;
                if rows * cols != features.len() {
                    return Err(FheError::BadRequest(format!(
                        "features length {} != rows*cols {}",
                        features.len(),
                        rows * cols
                    )));
                }
                let deadline_ms = match j.get("deadline_ms") {
                    None => None,
                    Some(v) => Some(
                        v.as_i64()
                            .filter(|&ms| ms >= 0)
                            .ok_or_else(|| bad("'deadline_ms' must be a non-negative integer"))?
                            as u64,
                    ),
                };
                Ok(Request::Infer { engine, target, features, rows, cols, deadline_ms })
            }
            Some("decode") => {
                let id = |field: &'static str| {
                    j.get(field)
                        .and_then(|v| v.as_i64())
                        .filter(|&v| v >= 0)
                        .map(|v| v as u64)
                        .ok_or_else(|| bad(&format!("'{field}' must be a non-negative integer")))
                };
                let mechanism = j
                    .get("mechanism")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("missing 'mechanism'"))?
                    .to_string();
                let deadline_ms = match j.get("deadline_ms") {
                    None => None,
                    Some(v) => Some(
                        v.as_i64()
                            .filter(|&ms| ms >= 0)
                            .ok_or_else(|| bad("'deadline_ms' must be a non-negative integer"))?
                            as u64,
                    ),
                };
                Ok(Request::Decode {
                    session: id("session")?,
                    mechanism,
                    stream: id("stream")?,
                    blob: id("blob")?,
                    prefill: j.get("prefill").and_then(|v| v.as_bool()).unwrap_or(false),
                    deadline_ms,
                })
            }
            Some("release_cache") => {
                let id = |field: &'static str| {
                    j.get(field)
                        .and_then(|v| v.as_i64())
                        .filter(|&v| v >= 0)
                        .map(|v| v as u64)
                        .ok_or_else(|| bad(&format!("'{field}' must be a non-negative integer")))
                };
                Ok(Request::ReleaseCache { session: id("session")?, stream: id("stream")? })
            }
            Some("drop_session") => {
                let session = j
                    .get("session")
                    .and_then(|v| v.as_i64())
                    .filter(|&v| v >= 0)
                    .map(|v| v as u64)
                    .ok_or_else(|| bad("'session' must be a non-negative integer"))?;
                Ok(Request::DropSession { session })
            }
            other => Err(FheError::BadRequest(format!("unknown op {other:?}"))),
        }
    }

    pub fn to_json_line(&self) -> String {
        match self {
            Request::Ping => r#"{"op":"ping"}"#.to_string(),
            Request::Metrics => r#"{"op":"metrics"}"#.to_string(),
            Request::Shutdown => r#"{"op":"shutdown"}"#.to_string(),
            Request::Infer { engine, target, features, rows, cols, deadline_ms } => {
                let key = if engine == "pjrt" { "model" } else { "mechanism" };
                let mut fields = vec![
                    ("op", Json::str("infer")),
                    ("engine", Json::str(engine.clone())),
                    (key, Json::str(target.clone())),
                    (
                        "features",
                        Json::arr(features.iter().map(|&f| Json::num(f as f64)).collect()),
                    ),
                    ("rows", Json::num(*rows as f64)),
                    ("cols", Json::num(*cols as f64)),
                ];
                if let Some(ms) = deadline_ms {
                    fields.push(("deadline_ms", Json::num(*ms as f64)));
                }
                Json::obj(fields).to_string()
            }
            Request::Decode { session, mechanism, stream, blob, prefill, deadline_ms } => {
                let mut fields = vec![
                    ("op", Json::str("decode")),
                    ("session", Json::num(*session as f64)),
                    ("mechanism", Json::str(mechanism.clone())),
                    ("stream", Json::num(*stream as f64)),
                    ("blob", Json::num(*blob as f64)),
                    ("prefill", Json::Bool(*prefill)),
                ];
                if let Some(ms) = deadline_ms {
                    fields.push(("deadline_ms", Json::num(*ms as f64)));
                }
                Json::obj(fields).to_string()
            }
            Request::ReleaseCache { session, stream } => Json::obj(vec![
                ("op", Json::str("release_cache")),
                ("session", Json::num(*session as f64)),
                ("stream", Json::num(*stream as f64)),
            ])
            .to_string(),
            Request::DropSession { session } => Json::obj(vec![
                ("op", Json::str("drop_session")),
                ("session", Json::num(*session as f64)),
            ])
            .to_string(),
        }
    }
}

/// Build a success response line. `result_blob` (typed encrypted-result
/// reference) is included only when present. Ids beyond the JSON-number
/// exact range (2⁵³) become an error line instead of silently rounding
/// to a neighboring blob — defensive future-proofing: today's TCP
/// request path is features-only and never produces encrypted results
/// (the in-process coordinator API, which the encrypted clients use,
/// carries the id as an exact `u64`), and a sequential id counter can
/// only pass 2⁵³ if an operator deliberately partitions the id space
/// with `Session::set_next_blob_id`. Known limitation if that ever
/// combines with encrypted-over-TCP serving: by the time this line is
/// built the engine has already registered the result bundle, so the
/// error leaves it in the session store — such a protocol must free or
/// re-expose it through a session-level API, not this response line.
pub fn ok_response(output: &[f32], result_blob: Option<u64>, latency_s: f64) -> String {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("output", Json::arr(output.iter().map(|&f| Json::num(f as f64)).collect())),
    ];
    if let Some(id) = result_blob {
        if id >= (1u64 << 53) {
            return error_response(&FheError::Protocol(format!(
                "result blob id {id} exceeds the JSON-number exact range"
            )));
        }
        fields.push(("result_blob", Json::num(id as f64)));
    }
    fields.push(("latency_s", Json::num(latency_s)));
    Json::obj(fields).to_string()
}

/// Build an error response line: human-readable `error` plus the stable
/// machine-readable `error_code` ([`FheError::code`]).
pub fn error_response(err: &FheError) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(err.to_string())),
        ("error_code", Json::str(err.code())),
    ])
    .to_string()
}

/// Build a free-form text response (metrics).
pub fn text_response(text: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("text", Json::str(text))]).to_string()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_infer() {
        let req = Request::Infer {
            engine: "quant".into(),
            target: "inhibitor".into(),
            features: vec![1.0, 2.0, 3.0, 4.0],
            rows: 2,
            cols: 2,
            deadline_ms: None,
        };
        let line = req.to_json_line();
        assert_eq!(Request::parse(&line).unwrap(), req);
    }

    #[test]
    fn parse_roundtrip_infer_with_deadline() {
        let req = Request::Infer {
            engine: "quant".into(),
            target: "inhibitor".into(),
            features: vec![1.0],
            rows: 1,
            cols: 1,
            deadline_ms: Some(250),
        };
        let line = req.to_json_line();
        assert!(line.contains("deadline_ms"), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), req);
        // Negative budgets are rejected, not wrapped into huge u64s.
        let neg = r#"{"op":"infer","engine":"quant","mechanism":"x","features":[1],"rows":1,"cols":1,"deadline_ms":-5}"#;
        let err = Request::parse(neg).unwrap_err();
        assert_eq!(err.code(), "bad_request");
    }

    #[test]
    fn parse_roundtrip_decode_and_release_cache() {
        let prefill = Request::Decode {
            session: 3,
            mechanism: "inhibitor@h2xL2".into(),
            stream: 11,
            blob: 42,
            prefill: true,
            deadline_ms: None,
        };
        assert_eq!(Request::parse(&prefill.to_json_line()).unwrap(), prefill);
        let step = Request::Decode {
            session: 3,
            mechanism: "decode/softmax@h1xL1".into(),
            stream: 11,
            blob: 43,
            prefill: false,
            deadline_ms: Some(500),
        };
        let line = step.to_json_line();
        assert!(line.contains("deadline_ms"), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), step);
        // `prefill` defaults to false when absent.
        let bare = r#"{"op":"decode","session":1,"mechanism":"m","stream":2,"blob":3}"#;
        match Request::parse(bare).unwrap() {
            Request::Decode { prefill, .. } => assert!(!prefill),
            other => panic!("want Decode, got {other:?}"),
        }
        let rel = Request::ReleaseCache { session: 3, stream: 11 };
        assert_eq!(Request::parse(&rel.to_json_line()).unwrap(), rel);
        let drop = Request::DropSession { session: 3 };
        assert_eq!(Request::parse(&drop.to_json_line()).unwrap(), drop);
    }

    #[test]
    fn decode_rejects_bad_ids_with_typed_errors() {
        for line in [
            r#"{"op":"decode","mechanism":"m","stream":2,"blob":3}"#,
            r#"{"op":"decode","session":-1,"mechanism":"m","stream":2,"blob":3}"#,
            r#"{"op":"decode","session":1,"stream":2,"blob":3}"#,
            r#"{"op":"release_cache","session":1}"#,
            r#"{"op":"release_cache","session":1,"stream":-2}"#,
            r#"{"op":"drop_session"}"#,
            r#"{"op":"drop_session","session":-1}"#,
        ] {
            assert_eq!(Request::parse(line).unwrap_err().code(), "bad_request", "{line}");
        }
    }

    #[test]
    fn parse_control_ops() {
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(Request::parse(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(Request::parse(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn rejects_malformed_with_typed_errors() {
        // Unparseable bytes are a protocol error; structurally-valid JSON
        // with bad fields is a bad request.
        assert_eq!(Request::parse("not json").unwrap_err().code(), "protocol");
        assert_eq!(Request::parse(r#"{"op":"teleport"}"#).unwrap_err().code(), "bad_request");
        let err = Request::parse(
            r#"{"op":"infer","engine":"quant","mechanism":"x","features":[1],"rows":2,"cols":2}"#,
        )
        .unwrap_err();
        assert_eq!(err.code(), "bad_request");
    }

    #[test]
    fn responses_are_valid_json() {
        for s in [
            ok_response(&[1.0, -2.5], None, 0.01),
            ok_response(&[], Some((1u64 << 24) + 7), 0.01),
            error_response(&FheError::Internal("boom".into())),
            text_response("a\nb"),
        ] {
            crate::util::json::Json::parse(&s).unwrap();
        }
        let with_ref = ok_response(&[], Some(42), 0.5);
        let j = crate::util::json::Json::parse(&with_ref).unwrap();
        assert_eq!(j.get("result_blob").and_then(|v| v.as_i64()), Some(42));
        let without = ok_response(&[1.0], None, 0.5);
        let j = crate::util::json::Json::parse(&without).unwrap();
        assert!(j.get("result_blob").is_none(), "absent unless encrypted");
        // Past the JSON-number exact range the encoder refuses loudly
        // instead of rounding to a neighboring blob id.
        let too_big = ok_response(&[], Some(1u64 << 53), 0.5);
        let j = crate::util::json::Json::parse(&too_big).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
    }

    #[test]
    fn error_lines_carry_stable_codes_that_roundtrip() {
        let err = FheError::DeadlineExceeded("late by 3 levels".into());
        let line = error_response(&err);
        let j = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(j.get("error_code").and_then(|v| v.as_str()), Some("deadline_exceeded"));
        // A client rebuilds the typed error from the wire fields.
        let rebuilt = FheError::from_code(
            j.get("error_code").and_then(|v| v.as_str()).unwrap(),
            j.get("error").and_then(|v| v.as_str()).unwrap(),
        );
        assert_eq!(rebuilt, err);
    }
}
