//! `inhibitor` — leader entrypoint + CLI (L3).
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!   serve         run the TCP serving coordinator
//!   infer         one-shot inference through the quantized engine
//!   encrypt-infer end-to-end encrypted attention demo
//!   params        run the TFHE parameter optimizer (Table 2)
//!   tables        print paper-table reproductions (2 and 3; 4 via bench)
//!   selftest      fast whole-stack smoke test
//!   client        send a request to a running server

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{BatchPolicy, Coordinator, Payload, RoutePolicy};
use inhibitor::model::{ModelConfig, QTransformer};
use inhibitor::util::prng::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let code = match cmd {
        "serve" => cmd_serve(rest),
        "infer" => cmd_infer(rest),
        "encrypt-infer" => cmd_encrypt_infer(rest),
        "params" => cmd_params(rest),
        "tables" => cmd_tables(rest),
        "selftest" => cmd_selftest(),
        "client" => cmd_client(rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "inhibitor — ReLU and addition-based attention under TFHE\n\
         \n\
         USAGE: inhibitor <command> [options]\n\
         \n\
         COMMANDS:\n\
           serve [--addr 127.0.0.1:7474] [--artifacts artifacts] [--mechanism inhibitor]\n\
                 [--threads N] [--storage-budget BYTES] [--storage-dir DIR]\n\
               Start the serving coordinator (quant + PJRT engines); --threads\n\
               sets the PBS worker budget for encrypted engines;\n\
               --storage-budget caps the hot ciphertext tier in bytes (cold\n\
               bundles spill to the blob sink; 0 spills everything) and\n\
               --storage-dir spills to disk under DIR instead of memory.\n\
           infer [--mechanism inhibitor] [--seq 16] [--dim 32] [--deadline-ms N]\n\
               One-shot quantized inference on random features; --deadline-ms\n\
               attaches a request deadline (expired requests fail with the\n\
               stable error code 'deadline_exceeded').\n\
           encrypt-infer [--mechanism inhibitor] [--seq 2] [--bits 5] [--threads N]\n\
                         [--heads H] [--shared-kv] [--layers L] [--decode-steps N]\n\
               Generate keys, encrypt Q/K/V, run encrypted attention, decrypt.\n\
               --heads > 1 serves an H-head block as ONE fused circuit plan\n\
               (--shared-kv: multi-query layout, one K/V for all heads);\n\
               --layers >= 1 runs FULL transformer blocks (attention + W_O +\n\
               residuals + ReLU FFN, demo weights) stacked into one plan —\n\
               the input is then the residual stream x, not Q/K/V;\n\
               --decode-steps N (with --layers >= 1) switches to incremental\n\
               decode: prefill --seq tokens once, then stream N single-token\n\
               steps over the encrypted KV-cache, each pinned against the\n\
               streaming mirror and the profile_step closed form (O(t*d) per\n\
               step, no prefix recompute; --seq 1 is the gated-RNN T=1 mode;\n\
               keep --seq + N <= 3 for mirror-exact demo weights at --bits 5);\n\
               --threads overrides the FHE_THREADS PBS worker count.\n\
           params [--seq 2,4,8,16]\n\
               Run the TFHE parameter optimizer (paper Table 2).\n\
           tables [--quick]\n\
               Print Table 2 + Table 3 reproductions.\n\
           selftest\n\
               Whole-stack smoke test (quant, FHE, PJRT if artifacts exist).\n\
           client [--addr 127.0.0.1:7474] [--op ping|metrics|shutdown|infer]\n\
                  [--mechanism inhibitor] [--deadline-ms N]\n\
               Talk to a running server ('infer' sends random features;\n\
               --deadline-ms rides the wire as the request's budget).\n\
         \n\
         ENVIRONMENT:\n\
           FHE_THREADS   PBS worker threads (default: all cores)\n\
           FHE_NO_REWRITE  disable the circuit-plan rewrite passes\n\
           FHE_FAULTS    deterministic fault injection for the serving\n\
                         path, e.g. 'panic@pbs:17,deadline@level:2'\n\
                         (see rust/src/tfhe/faults.rs)\n\
           FHE_STORAGE_BUDGET  hot ciphertext-tier byte budget (LRU spill\n\
                         past it; 0 spills everything; default 256 MiB)\n\
           FHE_STORAGE_DIR  spill evicted ciphertext bundles and parked\n\
                         server keys to this directory instead of memory"
    );
}

fn flag(args: &[String], name: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn cmd_serve(args: &[String]) -> i32 {
    let addr = flag(args, "--addr", "127.0.0.1:7474");
    let artifacts = flag(args, "--artifacts", "artifacts");
    let mech_s = flag(args, "--mechanism", "inhibitor");
    let threads: usize = flag(args, "--threads", "0").parse().unwrap_or(0);
    let storage_budget = flag(args, "--storage-budget", "");
    let storage_dir = flag(args, "--storage-dir", "");
    // The serve flags are sugar over the env knobs Coordinator::new
    // reads, so one storage configuration path serves both.
    if !storage_budget.is_empty() {
        std::env::set_var("FHE_STORAGE_BUDGET", &storage_budget);
    }
    if !storage_dir.is_empty() {
        std::env::set_var("FHE_STORAGE_DIR", &storage_dir);
    }
    let Some(mechanism) = Mechanism::parse(&mech_s) else {
        eprintln!("unknown mechanism '{mech_s}'");
        return 2;
    };
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    if threads > 0 {
        // PBS worker budget for encrypted engines registered on this
        // coordinator (default: FHE_THREADS env or all cores).
        c.set_fhe_threads(threads);
    }
    // Quantized engines for both mechanisms (trained-weight loading uses
    // artifacts/<model>.weights.bin when present; random weights are a
    // stand-in for the serve demo otherwise).
    for m in [Mechanism::DotProduct, mechanism] {
        // Match the AOT model contract (seq 16, 2 input features).
        let mut cfg = ModelConfig::small(m, 16, 32);
        cfg.in_features = 2;
        let model = load_or_random(&artifacts, m, cfg);
        c.add_quant_engine(m.name(), model, BatchPolicy::default());
    }
    #[cfg(feature = "xla")]
    {
        if std::path::Path::new(&artifacts).join("manifest.json").exists() {
            for name in ["model_inhibitor", "model_dotprod"] {
                c.add_pjrt_model(artifacts.clone().into(), name, BatchPolicy::default());
            }
            println!("PJRT engines registered from {artifacts}/");
        } else {
            println!("no {artifacts}/manifest.json — serving quantized engines only");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("built without `xla` — serving quantized engines only ({artifacts}/ ignored)");
    let c = Arc::new(c);
    println!("listening on {addr} (JSON-lines; see rust/src/server/proto.rs)");
    match inhibitor::server::serve(c, &addr, |a| println!("bound {a}")) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("server error: {e}");
            1
        }
    }
}

fn load_or_random(artifacts: &str, m: Mechanism, cfg: ModelConfig) -> QTransformer {
    let wpath = format!("{artifacts}/model_{}.weights.bin", m.name());
    if let Ok(w) = inhibitor::model::weights::load_weights_file(&wpath) {
        // Config travels with the manifest; the small default matches aot.py.
        if let Ok(model) = inhibitor::model::weights::build_model(&cfg, &w) {
            println!("loaded weights {wpath}");
            return model;
        }
    }
    QTransformer::random(cfg, 42)
}

fn cmd_infer(args: &[String]) -> i32 {
    let mech_s = flag(args, "--mechanism", "inhibitor");
    let seq: usize = flag(args, "--seq", "16").parse().unwrap_or(16);
    let dim: usize = flag(args, "--dim", "32").parse().unwrap_or(32);
    let Some(mechanism) = Mechanism::parse(&mech_s) else {
        eprintln!("unknown mechanism '{mech_s}'");
        return 2;
    };
    let cfg = ModelConfig::small(mechanism, seq, dim);
    let in_features = cfg.in_features;
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    c.add_quant_engine(mechanism.name(), QTransformer::random(cfg, 7), BatchPolicy::default());
    let mut rng = Xoshiro256::new(1);
    let features: Vec<f32> =
        (0..seq * in_features).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
    let deadline_ms: Option<u64> = flag(args, "--deadline-ms", "").parse().ok();
    let mut req = inhibitor::coordinator::InferRequest::new(
        0,
        inhibitor::coordinator::EnginePath::QuantInt(mechanism.name().into()),
        Payload::Features(features, (seq, in_features)),
    );
    if let Some(ms) = deadline_ms {
        req = req.with_deadline(std::time::Instant::now() + Duration::from_millis(ms));
    }
    match c.infer_request_blocking(req, Duration::from_secs(30)) {
        Ok(resp) => match resp.error {
            None => {
                println!(
                    "engine={} latency={:.3}ms output={:?}",
                    resp.engine,
                    resp.latency_s * 1e3,
                    resp.output
                );
                0
            }
            Some(e) => {
                eprintln!("inference failed [{}]: {e}", e.code());
                1
            }
        },
        Err(e) => {
            eprintln!("inference failed [{}]: {e}", e.code());
            1
        }
    }
}

fn cmd_encrypt_infer(args: &[String]) -> i32 {
    use inhibitor::fhe_circuits::{
        CtMatrix, DecodeFhe, DecodeMirror, DotProductFhe, InhibitorFhe, InhibitorSignedFhe,
        ModelFhe, MultiHeadFhe,
    };
    use inhibitor::tensor::ITensor;
    use inhibitor::tfhe::{bootstrap, ClientKey, FheContext, TfheParams};
    let mech_s = flag(args, "--mechanism", "inhibitor");
    let Some(mechanism) = Mechanism::parse(&mech_s) else {
        eprintln!("unknown mechanism '{mech_s}'");
        return 2;
    };
    let seq: usize = flag(args, "--seq", "2").parse().unwrap_or(2);
    let bits: u32 = flag(args, "--bits", "5").parse().unwrap_or(5);
    let threads: usize = flag(args, "--threads", "0").parse().unwrap_or(0);
    let heads: usize = flag(args, "--heads", "1").parse().unwrap_or(1).max(1);
    let layers: usize = flag(args, "--layers", "0").parse().unwrap_or(0);
    let shared_kv = has_flag(args, "--shared-kv");
    let dim = 2usize; // per-head width; the paper's encrypted experiments use d=2
    let mut rng = Xoshiro256::new(2024);
    // The signed circuit's V⁺/V⁻ pairs pack into shared blind rotations
    // when the parameter set carries multi-value headroom — give it one.
    // Stacked signed blocks carry requant+ReLU+split trios, which need
    // ϑ = 2 to share one rotation per trio.
    let params = if mechanism == Mechanism::InhibitorSigned {
        if layers >= 2 {
            TfheParams::test_multi_lut_theta(bits, 2)
        } else {
            TfheParams::test_multi_lut(bits)
        }
    } else {
        TfheParams::test_for_bits(bits)
    };
    println!(
        "generating keys (n={}, N={}, {} message bits)...",
        params.lwe_dim, params.poly_size, bits
    );
    let ck = ClientKey::generate(params, &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    if threads > 0 {
        ctx.set_threads(threads);
    }
    println!("PBS engine: {} worker thread(s)", ctx.threads());
    if layers >= 1 {
        // Full transformer blocks stacked into ONE circuit plan: the
        // input is the residual stream x (demo weights keep every
        // intermediate inside the demo code range for x ∈ [−1, 1]).
        let d_model = heads * dim;
        let model = ModelFhe::demo(
            mechanism,
            d_model,
            heads,
            layers,
            shared_kv && heads > 1,
            d_model,
            2024,
        );
        let decode_steps: usize = flag(args, "--decode-steps", "0").parse().unwrap_or(0);
        if decode_steps > 0 {
            // Incremental decode: prefill --seq tokens once, then stream
            // --decode-steps single-token steps against the encrypted
            // KV-cache — per-step work is O(t·d), the prefix is never
            // recomputed. `--seq 1` is the gated-RNN degenerate mode
            // (every plan is the T = 1 recurrence).
            let decode = DecodeFhe::new(model);
            let total = seq + decode_steps;
            let x = ITensor::random(&[total, d_model], -1, 1, &mut rng);
            let mut mirror =
                DecodeMirror::new(&decode.model, ctx.enc.min_signed(), ctx.enc.max_signed());
            let xp = ITensor::from_vec(&[seq, d_model], x.data[..seq * d_model].to_vec());
            println!("encrypting {} ciphertexts (prefill [T, D])...", seq * d_model);
            let cx = CtMatrix::encrypt(&xp, &ctx, &ck, &mut rng);
            bootstrap::reset_pbs_count();
            bootstrap::reset_blind_rotation_count();
            let t0 = std::time::Instant::now();
            let (out, mut cache) = decode.prefill(&ctx, &cx);
            let dt = t0.elapsed();
            let prefill_ok = out.decrypt(&ctx, &ck) == mirror.prefill(&xp);
            println!(
                "prefill T={seq}: {} PBS ({} blind rotations) in {:.3}s — cache bundle {} \
                 ciphertexts, mirror {}",
                bootstrap::pbs_count(),
                bootstrap::blind_rotation_count(),
                dt.as_secs_f64(),
                cache.len(),
                if prefill_ok { "ok" } else { "MISMATCH (retry with a larger --bits)" }
            );
            for i in 0..decode_steps {
                let t_cached = seq + i;
                let row = ITensor::from_vec(
                    &[1, d_model],
                    x.data[t_cached * d_model..(t_cached + 1) * d_model].to_vec(),
                );
                let crow = CtMatrix::encrypt(&row, &ctx, &ck, &mut rng);
                bootstrap::reset_pbs_count();
                bootstrap::reset_blind_rotation_count();
                let t0 = std::time::Instant::now();
                let (out_row, next_cache) = decode.step(&ctx, &crow.data, cache);
                cache = next_cache;
                let dt = t0.elapsed();
                let (pbs, rot) =
                    (bootstrap::pbs_count(), bootstrap::blind_rotation_count());
                let prof = inhibitor::optimizer::profile_step(
                    mechanism,
                    t_cached,
                    d_model,
                    heads,
                    layers,
                    d_model,
                    shared_kv && heads > 1,
                    ctx.max_multi_lut(),
                );
                let m_row = mirror.step(&row.data);
                let dec = CtMatrix { rows: 1, cols: d_model, data: out_row }.decrypt(&ctx, &ck);
                println!(
                    "step {}: prefix {t_cached} -> {}: {pbs} PBS ({rot} rotations) in {:.3}s \
                     — closed form {} PBS ({} rotations), mirror {}",
                    i + 1,
                    t_cached + 1,
                    dt.as_secs_f64(),
                    prof.pbs_count,
                    prof.blind_rotations,
                    if dec.data == m_row { "ok" } else { "MISMATCH" }
                );
            }
            println!(
                "decode stream done: {seq} prefill token(s) + {decode_steps} step(s), \
                 per-step cost linear in the prefix (no T\u{b2} recompute)"
            );
            return 0;
        }
        let x = ITensor::random(&[seq, d_model], -1, 1, &mut rng);
        println!("encrypting {} ciphertexts (residual stream [T, D])...", seq * d_model);
        let cx = CtMatrix::encrypt(&x, &ctx, &ck, &mut rng);
        bootstrap::reset_pbs_count();
        bootstrap::reset_blind_rotation_count();
        let t0 = std::time::Instant::now();
        let h = model.forward(&ctx, &cx);
        let dt = t0.elapsed();
        let out = h.decrypt(&ctx, &ck);
        let mirror = model.mirror(&x, ctx.enc.min_signed(), ctx.enc.max_signed());
        println!(
            "mechanism={} T={} d={} heads={heads} layers={layers}{}: {} PBS ({} blind \
             rotations) in {:.3}s ({:.1} ms/PBS) — one fused {}-level plan",
            mechanism.name(),
            seq,
            dim,
            if shared_kv && heads > 1 { " shared-kv" } else { "" },
            bootstrap::pbs_count(),
            bootstrap::blind_rotation_count(),
            dt.as_secs_f64(),
            dt.as_secs_f64() * 1e3 / bootstrap::pbs_count().max(1) as f64,
            model.plan_for(&ctx, seq).levels(),
        );
        println!("decrypted out = {:?}", out.data);
        if out == mirror {
            println!("plaintext mirror check: ok");
        } else {
            println!(
                "plaintext mirror check: MISMATCH (expected {:?}) — likely an intermediate \
                 overflowed {bits} message bits; retry with a larger --bits",
                mirror.data
            );
        }
        return 0;
    }
    // Signed inhibition exercises negative values; the other circuits
    // keep the non-negative range their mirrors assume.
    let v_range = if mechanism == Mechanism::InhibitorSigned { (-3, 3) } else { (0, 3) };
    let (d_model, kv_cols) =
        (heads * dim, if shared_kv && heads > 1 { dim } else { heads * dim });
    let q = ITensor::random(&[seq, d_model], -2, 2, &mut rng);
    let k = ITensor::random(&[seq, kv_cols], -2, 2, &mut rng);
    let v = ITensor::random(&[seq, kv_cols], v_range.0, v_range.1, &mut rng);
    println!("encrypting {} ciphertexts...", seq * (d_model + 2 * kv_cols));
    let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
    let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
    let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
    bootstrap::reset_pbs_count();
    bootstrap::reset_blind_rotation_count();
    let t0 = std::time::Instant::now();
    let (h, mirror) = if heads > 1 {
        // One fused H-head circuit plan: the rewrite passes optimize
        // across head boundaries (shared-KV value splits dedupe + pack).
        let mh = MultiHeadFhe::new(mechanism, dim, heads, shared_kv && heads > 1);
        let h = mh.forward(&ctx, &cq, &ckk, &cv);
        let mirror = mh.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
        (h, mirror)
    } else {
        match mechanism {
            Mechanism::DotProduct => {
                let head = DotProductFhe::new(dim, 2);
                let h = head.forward(&ctx, &cq, &ckk, &cv);
                let m = head.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
                (h, m)
            }
            Mechanism::InhibitorSigned => {
                let head = InhibitorSignedFhe::new(dim, 1);
                let h = head.forward(&ctx, &cq, &ckk, &cv);
                let m = head.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
                (h, m)
            }
            _ => {
                let head = InhibitorFhe::new(dim, 1);
                let h = head.forward(&ctx, &cq, &ckk, &cv);
                let m = head.mirror(&q, &k, &v, ctx.enc.max_signed());
                (h, m)
            }
        }
    };
    let dt = t0.elapsed();
    let out = h.decrypt(&ctx, &ck);
    println!(
        "mechanism={} T={} d={}{}: {} PBS ({} blind rotations) in {:.3}s ({:.1} ms/PBS)",
        mechanism.name(),
        seq,
        dim,
        if heads > 1 {
            format!(" heads={heads}{}", if shared_kv { " shared-kv" } else { "" })
        } else {
            String::new()
        },
        bootstrap::pbs_count(),
        bootstrap::blind_rotation_count(),
        dt.as_secs_f64(),
        dt.as_secs_f64() * 1e3 / bootstrap::pbs_count().max(1) as f64
    );
    println!("decrypted H = {:?}", out.data);
    if out == mirror {
        println!("plaintext mirror check: ok");
    } else {
        // Informative, not fatal: the mirror equality guarantee assumes
        // every linear intermediate fits the chosen code width, which a
        // demo-sized `--bits` cannot promise for all mechanisms (wrapped
        // torus sums vs the mirror's clamped i64 sums). Raise --bits to
        // tighten the demo.
        println!(
            "plaintext mirror check: MISMATCH (expected {:?}) — likely an \
             intermediate overflowed {bits} message bits; retry with a larger --bits",
            mirror.data
        );
    }
    0
}

fn cmd_params(args: &[String]) -> i32 {
    let _seqs: Vec<usize> = flag(args, "--seq", "2,4,8,16")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    // Calibrate the cost→seconds conversion from a tiny measured PBS.
    let fps = calibrated_flops();
    inhibitor::bench_tables::print_table2(fps);
    0
}

fn calibrated_flops() -> f64 {
    use inhibitor::tfhe::{bootstrap::Lut, ClientKey, Encoder, TfheParams};
    let mut rng = Xoshiro256::new(3);
    let p = TfheParams::test_small();
    let ck = ClientKey::generate(p, &mut rng);
    let sk = ck.server_key(&mut rng);
    let enc = Encoder::new(p);
    let ct = enc.encrypt_raw(1, &ck, &mut rng);
    let lut = Lut::from_fn(&p, |m| m);
    let t0 = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        let _ = sk.pbs(&ct, &lut);
    }
    let per_pbs = t0.elapsed().as_secs_f64() / reps as f64;
    inhibitor::optimizer::cost::calibrate_flops_per_sec(per_pbs, &p)
}

fn cmd_tables(args: &[String]) -> i32 {
    let quick = has_flag(args, "--quick");
    let fps = calibrated_flops();
    inhibitor::bench_tables::print_table2(fps);
    let target = if quick { Duration::from_millis(50) } else { Duration::from_millis(300) };
    let cells = inhibitor::bench_tables::run_table3(&[32, 64, 128, 256], 64, target);
    inhibitor::bench_tables::print_table3(&cells);
    println!("\n(Table 1: `make table1`; Table 4: `cargo bench --bench table4_encrypted`)");
    0
}

fn cmd_selftest() -> i32 {
    println!("[1/3] quantized engines...");
    for m in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
        let cfg = ModelConfig::small(m, 8, 16);
        let model = QTransformer::random(cfg, 1);
        let mut rng = Xoshiro256::new(2);
        let x = inhibitor::tensor::ITensor::random(&[8, 16], -50, 50, &mut rng);
        let out = model.forward(&inhibitor::model::ModelInput::Features(x));
        println!("  {} -> {:?} ok", m.name(), out.dims());
    }
    println!("[2/3] TFHE roundtrip + PBS...");
    {
        use inhibitor::tfhe::{bootstrap::Lut, ClientKey, Encoder, TfheParams};
        let mut rng = Xoshiro256::new(3);
        let p = TfheParams::test_small();
        let ck = ClientKey::generate(p, &mut rng);
        let sk = ck.server_key(&mut rng);
        let enc = Encoder::new(p);
        let lut = Lut::from_fn(&p, |m| (m + 1) % p.message_space());
        for m in 0..p.message_space() {
            let out = enc.decrypt_raw(&sk.pbs(&enc.encrypt_raw(m, &ck, &mut rng), &lut), &ck);
            assert_eq!(out, (m + 1) % p.message_space(), "PBS failed at {m}");
        }
        println!("  PBS successor-LUT exact over the whole message space ok");
    }
    println!("[3/3] PJRT artifacts...");
    #[cfg(feature = "xla")]
    match inhibitor::runtime::Registry::open("artifacts") {
        Ok(mut reg) => {
            println!(
                "  platform={} heads={} models={}",
                reg.platform(),
                reg.attention.len(),
                reg.models.len()
            );
            match reg.attention_engine("inhibitor", 32) {
                Ok(engine) => {
                    let z = vec![0.5f32; 32 * 64];
                    match engine.run_f32(&[z.clone(), z.clone(), z]) {
                        Ok(out) => {
                            println!("  attn_inhibitor_t32 executed, {} outputs ok", out.len())
                        }
                        Err(e) => {
                            eprintln!("  execute failed: {e:#}");
                            return 1;
                        }
                    }
                }
                Err(e) => eprintln!("  (skipping execute: {e:#})"),
            }
        }
        Err(e) => println!("  (no artifacts: {e:#} — run `make artifacts`)"),
    }
    #[cfg(not(feature = "xla"))]
    println!("  (built without the `xla` feature — skipped)");
    println!("selftest ok");
    0
}

fn cmd_client(args: &[String]) -> i32 {
    let addr = flag(args, "--addr", "127.0.0.1:7474");
    let op = flag(args, "--op", "ping");
    let mut client = match inhibitor::server::Client::connect(&addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            return 1;
        }
    };
    let result = match op.as_str() {
        "ping" => client.ping().map(|ok| format!("ping ok={ok}")),
        "metrics" => client.metrics(),
        "shutdown" => client.shutdown().map(|_| "shutdown sent".to_string()),
        "infer" => {
            // Matches the serve demo's quant engine contract (seq 16,
            // 2 input features).
            let mech = flag(args, "--mechanism", "inhibitor");
            let deadline_ms: Option<u64> = flag(args, "--deadline-ms", "").parse().ok();
            let mut rng = Xoshiro256::new(1);
            let features: Vec<f32> =
                (0..16 * 2).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
            client.infer_with_deadline("quant", &mech, features, 16, 2, deadline_ms).map(
                |r| match r {
                    Ok((out, lat)) => format!("latency={:.3}ms output={out:?}", lat * 1e3),
                    Err(e) => format!("inference failed [{}]: {e}", e.code()),
                },
            )
        }
        other => {
            eprintln!("unknown op '{other}'");
            return 2;
        }
    };
    match result {
        Ok(s) => {
            println!("{s}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
