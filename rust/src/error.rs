//! Library-wide typed error taxonomy for the serving path.
//!
//! Every fallible seam between the TFHE pool, the plan executor, the
//! coordinator and the wire protocol speaks [`FheError`] instead of bare
//! `String`s. Each variant carries a **stable machine-readable code**
//! ([`FheError::code`]) that travels on the wire as the response's
//! `error_code` field, next to the human-readable message — clients
//! branch on the code, humans read the message, and neither breaks when
//! the other is reworded.

/// Typed error for the serving path (coordinator, TFHE pool, executor,
/// wire protocol). Variants map 1:1 onto stable wire codes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FheError {
    /// The request or engine configuration does not fit the circuit plan
    /// (wrong bundle arity, unknown mechanism, zero heads, ...).
    PlanInvalid(String),
    /// No engine is registered under the request's batch key.
    UnknownEngine(String),
    /// A session or ciphertext bundle the request references does not
    /// exist (never created, already consumed, or evicted).
    KeyMissing(String),
    /// A LUT group or parameter combination exceeds the noise budget
    /// (e.g. a packed multi-value group past `max_multi_lut`).
    NoiseBudgetExceeded(String),
    /// A worker panicked while executing this request's work. The
    /// message carries the panic payload; the pool isolates the blast
    /// radius to the requests that depended on the poisoned job.
    WorkerPanic(String),
    /// The request's deadline expired; remaining PBS levels were
    /// abandoned (cooperative cancellation at level boundaries).
    DeadlineExceeded(String),
    /// The request's cancellation token fired.
    Cancelled,
    /// The scheduler is shutting down; queued requests drain with this
    /// error instead of hanging their receivers.
    Shutdown,
    /// Backpressure: the engine's bounded queue is full.
    QueueFull(String),
    /// A session exceeded its live decode-cache bundle cap; the client
    /// must `release_cache` (or finish a stream) before opening more.
    CacheOverflow(String),
    /// The request itself is malformed for the engine it targets
    /// (wrong payload kind, bad feature shape, ...).
    BadRequest(String),
    /// Wire-protocol error: unparseable line, unknown op, invalid UTF-8.
    Protocol(String),
    /// The storage tier failed: a blob sink I/O error, a corrupt spilled
    /// blob, or a park/attach precondition violation. Distinct from
    /// [`FheError::KeyMissing`] — the state *should* exist but could not
    /// be produced.
    Storage(String),
    /// Anything that does not fit the taxonomy (kept rare on purpose).
    Internal(String),
}

impl FheError {
    /// The stable machine-readable code for this error — the wire
    /// `error_code` field. Codes are API: never renamed, only added.
    pub fn code(&self) -> &'static str {
        match self {
            FheError::PlanInvalid(_) => "plan_invalid",
            FheError::UnknownEngine(_) => "unknown_engine",
            FheError::KeyMissing(_) => "key_missing",
            FheError::NoiseBudgetExceeded(_) => "noise_budget_exceeded",
            FheError::WorkerPanic(_) => "worker_panic",
            FheError::DeadlineExceeded(_) => "deadline_exceeded",
            FheError::Cancelled => "cancelled",
            FheError::Shutdown => "shutdown",
            FheError::QueueFull(_) => "queue_full",
            FheError::CacheOverflow(_) => "cache_overflow",
            FheError::BadRequest(_) => "bad_request",
            FheError::Protocol(_) => "protocol",
            FheError::Storage(_) => "storage",
            FheError::Internal(_) => "internal",
        }
    }

    /// Rebuild a typed error from its wire `(code, message)` pair — the
    /// client-side inverse of [`Self::code`]. Unknown codes (a newer
    /// server) land in [`FheError::Internal`] with the code prefixed, so
    /// nothing is silently dropped.
    pub fn from_code(code: &str, msg: &str) -> FheError {
        let m = msg.to_string();
        match code {
            "plan_invalid" => FheError::PlanInvalid(m),
            "unknown_engine" => FheError::UnknownEngine(m),
            "key_missing" => FheError::KeyMissing(m),
            "noise_budget_exceeded" => FheError::NoiseBudgetExceeded(m),
            "worker_panic" => FheError::WorkerPanic(m),
            "deadline_exceeded" => FheError::DeadlineExceeded(m),
            "cancelled" => FheError::Cancelled,
            "shutdown" => FheError::Shutdown,
            "queue_full" => FheError::QueueFull(m),
            "cache_overflow" => FheError::CacheOverflow(m),
            "bad_request" => FheError::BadRequest(m),
            "protocol" => FheError::Protocol(m),
            "storage" => FheError::Storage(m),
            "internal" => FheError::Internal(m),
            // A newer server's code: label it explicitly so the message
            // says *why* it landed in Internal, and keep the code even
            // when the server sent no message at all.
            other if m.is_empty() => FheError::Internal(format!("unknown error_code '{other}'")),
            other => FheError::Internal(format!("unknown error_code '{other}': {m}")),
        }
    }
}

impl std::fmt::Display for FheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FheError::PlanInvalid(m)
            | FheError::UnknownEngine(m)
            | FheError::KeyMissing(m)
            | FheError::NoiseBudgetExceeded(m)
            | FheError::WorkerPanic(m)
            | FheError::DeadlineExceeded(m)
            | FheError::QueueFull(m)
            | FheError::CacheOverflow(m)
            | FheError::BadRequest(m)
            | FheError::Protocol(m)
            | FheError::Storage(m)
            | FheError::Internal(m) => write!(f, "{m}"),
            FheError::Cancelled => write!(f, "request cancelled"),
            FheError::Shutdown => write!(f, "scheduler shutting down"),
        }
    }
}

impl std::error::Error for FheError {}

/// Render a `catch_unwind` payload as a message (panics carry either a
/// `&str` or a `String`; anything else gets a generic label). Shared by
/// the PBS pool's per-job isolation and the scheduler's batch guard.
pub fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "worker panicked (non-string payload)".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip_through_from_code() {
        let cases = vec![
            FheError::PlanInvalid("p".into()),
            FheError::UnknownEngine("u".into()),
            FheError::KeyMissing("k".into()),
            FheError::NoiseBudgetExceeded("n".into()),
            FheError::WorkerPanic("w".into()),
            FheError::DeadlineExceeded("d".into()),
            FheError::Cancelled,
            FheError::Shutdown,
            FheError::QueueFull("q".into()),
            FheError::CacheOverflow("c".into()),
            FheError::BadRequest("b".into()),
            FheError::Protocol("pr".into()),
            FheError::Storage("s".into()),
            FheError::Internal("i".into()),
        ];
        for e in cases {
            let back = FheError::from_code(e.code(), &e.to_string());
            assert_eq!(back.code(), e.code(), "{e:?}");
        }
    }

    #[test]
    fn unknown_code_is_preserved_not_dropped() {
        let e = FheError::from_code("quota_exhausted", "too many keys");
        assert_eq!(e.code(), "internal");
        assert_eq!(e.to_string(), "unknown error_code 'quota_exhausted': too many keys");
        // A codeless, messageless response still names the code instead
        // of collapsing to an empty Internal("").
        let e = FheError::from_code("quota_exhausted", "");
        assert_eq!(e.code(), "internal");
        assert_eq!(e.to_string(), "unknown error_code 'quota_exhausted'");
        // Round-tripping the *re-encoded* unknown error keeps the
        // original code visible in the message on the second hop too.
        let back = FheError::from_code(e.code(), &e.to_string());
        assert_eq!(back.code(), "internal");
        assert!(back.to_string().contains("quota_exhausted"), "{back}");
    }

    #[test]
    fn panic_message_extracts_common_payloads() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(p), "boom 7");
        let p = std::panic::catch_unwind(|| panic!("static")).unwrap_err();
        assert_eq!(panic_message(p), "static");
    }
}
