//! Post-training calibration (S1): pick per-tensor quantization ranges
//! from observed activation statistics.

use super::affine::QParams;
use crate::tensor::FTensor;

/// Running range observer (min/max calibration, optionally with a
/// percentile-style soft clip to shed outliers).
#[derive(Clone, Debug, Default)]
pub struct RangeObserver {
    samples: Vec<f32>,
}

impl RangeObserver {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, t: &FTensor) {
        // Keep absolute values; memory stays modest for calibration sets.
        self.samples.extend(t.data.iter().map(|x| x.abs()));
    }

    /// Absolute-max calibration.
    pub fn fit_maxabs(&self, bits: u32) -> QParams {
        let ma = self.samples.iter().cloned().fold(0.0f32, f32::max);
        QParams::fit_symmetric(ma, bits)
    }

    /// Percentile calibration: cover `pct` (e.g. 0.999) of observed |x|.
    /// Clipping the extreme tail shrinks the scale and improves resolution
    /// for the bulk of values — important at the 4–7 bit widths TFHE allows.
    pub fn fit_percentile(&self, bits: u32, pct: f64) -> QParams {
        assert!((0.0..=1.0).contains(&pct));
        if self.samples.is_empty() {
            return QParams::fit_symmetric(1.0, bits);
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() as f64 - 1.0) * pct).round() as usize;
        QParams::fit_symmetric(v[idx].max(1e-8), bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn maxabs_covers_everything() {
        let mut rng = Xoshiro256::new(17);
        let t = FTensor::randn(&[64, 64], 2.0, &mut rng);
        let mut obs = RangeObserver::new();
        obs.observe(&t);
        let q = obs.fit_maxabs(8);
        // No value should clamp.
        let ma = t.data.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        assert!(q.quantize(ma).abs() <= q.code_max());
        assert!((q.dequantize(q.quantize(ma)) - ma).abs() <= q.scale);
    }

    #[test]
    fn percentile_is_tighter_than_maxabs() {
        let mut rng = Xoshiro256::new(18);
        let t = FTensor::randn(&[128, 128], 1.0, &mut rng);
        let mut obs = RangeObserver::new();
        obs.observe(&t);
        let q_max = obs.fit_maxabs(8);
        let q_pct = obs.fit_percentile(8, 0.99);
        assert!(q_pct.scale < q_max.scale, "{} vs {}", q_pct.scale, q_max.scale);
    }

    #[test]
    fn empty_observer_defaults() {
        let obs = RangeObserver::new();
        let q = obs.fit_percentile(8, 0.999);
        assert!(q.scale > 0.0);
    }
}
