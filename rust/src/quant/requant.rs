//! Fixed-point requantization (S1).
//!
//! After an integer matmul (or a sum over the sequence), the accumulator
//! scale is the product of the input scales; to feed the next layer at its
//! code scale we multiply by `m = s_in / s_out`, a real number in (0, 1]
//! typically. Following the gemmlowd/TFLite convention we represent `m`
//! as a 32-bit integer multiplier and a right shift:
//! `m ≈ mult · 2^(-shift)`, applied with round-to-nearest. Under TFHE the
//! same step is a literal multiplication + PBS-free shift, i.e. cheap —
//! matching the paper's point that constant multiplication is fine.

/// A positive real factor ≈ `mult * 2^(-shift)`, `mult` in `[2^30, 2^31)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FixedMult {
    pub mult: i64,
    pub shift: u32,
}

impl FixedMult {
    /// Decompose a positive real factor. Panics if `m <= 0` or not finite.
    pub fn from_f64(m: f64) -> Self {
        assert!(m.is_finite() && m > 0.0, "requant factor must be positive, got {m}");
        // Normalize m = frac · 2^e with frac in [0.5, 1), fix a 31-bit
        // mantissa: total factor = mult · 2^(e−31), i.e. shift = 31 − e.
        let e = m.log2().floor() as i32 + 1; // 2^(e-1) <= m < 2^e
        let frac = m / 2f64.powi(e); // in [0.5, 1)
        let mult = (frac * (1i64 << 31) as f64).round() as i64; // [2^30, 2^31]
        let sh = 31 - e;
        assert!(sh >= 0, "factor {m} too large for fixed-point requant");
        FixedMult { mult: mult.min((1i64 << 31) - 1), shift: sh as u32 }
    }

    /// Apply to an accumulator with round-to-nearest (ties away from zero).
    #[inline]
    pub fn apply(&self, x: i64) -> i64 {
        let prod = (x as i128) * (self.mult as i128);
        let half = 1i128 << (self.shift.saturating_sub(1));
        let rounded = if prod >= 0 { prod + half } else { prod - half };
        (rounded >> self.shift) as i64
    }

    /// The real factor this represents.
    pub fn as_f64(&self) -> f64 {
        self.mult as f64 / 2f64.powi(self.shift as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng64;
    use crate::util::prop::{prop_assert, prop_check};

    #[test]
    fn represents_factor_accurately() {
        for m in [0.5, 0.123, 0.9999, 0.001, 1.0, 1.7, 3.999] {
            let f = FixedMult::from_f64(m);
            let rel = (f.as_f64() - m).abs() / m;
            assert!(rel < 1e-8, "m={m} rel={rel}");
        }
    }

    #[test]
    fn apply_matches_float_rounding() {
        prop_check("fixed-point apply ≈ float", 512, |rng| {
            let m = 0.001 + rng.next_f64() * 2.0;
            let f = FixedMult::from_f64(m);
            let x = rng.next_range_i64(-1_000_000, 1_000_000);
            let want = (x as f64 * m).round();
            let got = f.apply(x) as f64;
            // Allow one ulp of disagreement on exact .5 ties.
            prop_assert((got - want).abs() <= 1.0, &format!("m={m} x={x} got={got} want={want}"))
        });
    }

    #[test]
    fn zero_maps_to_zero() {
        let f = FixedMult::from_f64(0.37);
        assert_eq!(f.apply(0), 0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive() {
        let _ = FixedMult::from_f64(0.0);
    }
}
