//! Affine (scale / zero-point) quantization (S1).
//!
//! `real ≈ scale · (code − zero_point)`. The paper's integer experiments
//! use symmetric quantization (zero_point = 0) because the Inhibitor's
//! operations — |a−b|, subtract, ReLU — commute with symmetric scaling;
//! we also support asymmetric codes for activations after ReLU where the
//! range is one-sided.

use crate::tensor::{FTensor, ITensor};

/// Quantization parameters for one tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i64,
    /// Signed bit width of the code space (e.g. 8 → codes in [-128, 127]).
    pub bits: u32,
}

impl QParams {
    pub fn symmetric(scale: f32, bits: u32) -> Self {
        assert!(scale > 0.0, "scale must be positive");
        QParams { scale, zero_point: 0, bits }
    }

    /// Smallest/largest representable code.
    pub fn code_min(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    pub fn code_max(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Choose a symmetric scale that covers `[-max_abs, max_abs]`.
    pub fn fit_symmetric(max_abs: f32, bits: u32) -> Self {
        let max_code = ((1i64 << (bits - 1)) - 1) as f32;
        let ma = if max_abs <= 0.0 { 1e-8 } else { max_abs };
        QParams::symmetric(ma / max_code, bits)
    }

    /// Quantize one real value (round-half-away-from-zero, clamped).
    pub fn quantize(&self, x: f32) -> i64 {
        let code = (x / self.scale).round() as i64 + self.zero_point;
        code.clamp(self.code_min(), self.code_max())
    }

    /// Dequantize one code.
    pub fn dequantize(&self, code: i64) -> f32 {
        (code - self.zero_point) as f32 * self.scale
    }

    /// Quantize a float tensor.
    pub fn quantize_tensor(&self, t: &FTensor) -> ITensor {
        ITensor {
            shape: t.shape.clone(),
            data: t.data.iter().map(|&x| self.quantize(x)).collect(),
        }
    }

    /// Dequantize an integer tensor.
    pub fn dequantize_tensor(&self, t: &ITensor) -> FTensor {
        FTensor {
            shape: t.shape.clone(),
            data: t.data.iter().map(|&c| self.dequantize(c)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{Rng64, Xoshiro256};
    use crate::util::prop::{prop_assert, prop_check};

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        prop_check("quantize error ≤ scale/2", 256, |rng| {
            let bits = 4 + rng.next_bounded(12) as u32; // 4..=15
            let max_abs = 0.5 + rng.next_f64() as f32 * 10.0;
            let q = QParams::fit_symmetric(max_abs, bits);
            let x = (rng.next_f64() as f32 * 2.0 - 1.0) * max_abs;
            let err = (q.dequantize(q.quantize(x)) - x).abs();
            prop_assert(err <= q.scale * 0.5 + 1e-6, &format!("err {err} > scale/2 {}", q.scale))
        });
    }

    #[test]
    fn clamps_out_of_range() {
        let q = QParams::fit_symmetric(1.0, 8);
        assert_eq!(q.quantize(100.0), 127);
        assert_eq!(q.quantize(-100.0), -128);
    }

    #[test]
    fn code_bounds() {
        let q = QParams::symmetric(0.1, 8);
        assert_eq!(q.code_min(), -128);
        assert_eq!(q.code_max(), 127);
        let q4 = QParams::symmetric(0.1, 4);
        assert_eq!((q4.code_min(), q4.code_max()), (-8, 7));
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Xoshiro256::new(11);
        let t = crate::tensor::FTensor::randn(&[8, 8], 1.0, &mut rng);
        let q = QParams::fit_symmetric(4.0, 12);
        let deq = q.dequantize_tensor(&q.quantize_tensor(&t));
        // Values inside ±4 reconstruct within half a step.
        for (a, b) in t.data.iter().zip(deq.data.iter()) {
            if a.abs() < 4.0 {
                assert!((a - b).abs() <= q.scale, "{a} vs {b}");
            }
        }
    }
}
