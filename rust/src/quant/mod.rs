//! Quantization toolkit (S1): affine codes, calibration, fixed-point
//! requantization. See rust/DESIGN.md §2.

pub mod affine;
pub mod calib;
pub mod requant;

pub use affine::QParams;
pub use calib::RangeObserver;
pub use requant::FixedMult;
