//! TFHE parameter optimization (S7), after Bergerat et al. 2023: a noise
//! model, a cost model, circuit precision analysis, and an exhaustive
//! macro/micro parameter search. Regenerates the paper's Table 2.

pub mod cost;
pub mod noise;
pub mod precision;
pub mod search;

pub use precision::{
    profile, profile_block, profile_multihead, profile_prefill, profile_radix, profile_step,
    BlockProfile, CircuitProfile, MultiHeadProfile, RadixProfile, StepProfile,
};
pub use search::{optimize, table2, OptimizedParams, SearchConfig, Table2Row};
