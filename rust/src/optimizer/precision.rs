//! Circuit precision analysis (S7): worst-case bit-width tracking for the
//! two attention circuits, plus their PBS counts. Regenerates the "int" /
//! "uint" columns of the paper's Table 2 and feeds the parameter search.
//!
//! Since PR 2 the PBS and linear-op counts are **not** hand-derived
//! formulas: they are read off the circuit's [`CircuitPlan`] — the exact
//! DAG the executor runs — via [`CircuitPlan::pbs_count`] /
//! [`CircuitPlan::linear_op_count`], so the optimizer can never drift
//! from the implementation again. (The dot-product count grew accordingly:
//! the old formula omitted the probability ct×ct and the rescale PBS the
//! circuit always executed.)
//!
//! [`CircuitPlan`]: crate::tfhe::plan::CircuitPlan
//! [`CircuitPlan::pbs_count`]: crate::tfhe::plan::CircuitPlan::pbs_count
//! [`CircuitPlan::linear_op_count`]: crate::tfhe::plan::CircuitPlan::linear_op_count

use crate::attention::{HeadSplit, Mechanism};
use crate::fhe_circuits::{DotProductFhe, InhibitorFhe, InhibitorSignedFhe};
use crate::tfhe::plan::{CircuitPlan, PlanRewriter, RewriteConfig};
use crate::tfhe::radix::RadixSpec;

/// Profile-side counts of one circuit plan: LUT evaluations and linear
/// ops after the always-safe CSE pass (what `forward()` executes on any
/// parameter set), plus blind rotations at the smallest real packing
/// budget (ϑ = 1, groups of 2 — the budget `TfheParams::test_multi_lut`
/// sets), so Table-2-style reports can show the multi-value saving.
fn plan_counts(plan: CircuitPlan) -> (u64, u64, u64) {
    let (cse, _) = PlanRewriter::new(RewriteConfig::cse_only()).rewrite(plan);
    let pbs = cse.pbs_count();
    let linear = cse.linear_op_count();
    let (packed, _) =
        PlanRewriter::new(RewriteConfig { cse: false, max_multi_lut: 2 }).rewrite(cse);
    (pbs, packed.blind_rotation_count(), linear)
}

/// Static profile of one encrypted attention circuit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CircuitProfile {
    pub mechanism: Mechanism,
    pub seq_len: usize,
    pub dim: usize,
    /// Signed input code width (bits incl. sign).
    pub input_bits: u32,
    /// Max signed width at any point of the circuit ("int" column).
    pub int_bits: u32,
    /// Max unsigned width at any point ("uint" column).
    pub uint_bits: u32,
    /// LUT evaluations for one forward pass (after the always-safe CSE
    /// rewrite — what the serving path actually executes).
    pub pbs_count: u64,
    /// Blind rotations for one forward pass at a packing budget of
    /// `2^ϑ ≥ 2`. Equals `pbs_count` for circuits the packing pass
    /// leaves untouched; the parameter search still costs by
    /// `pbs_count` (a conservative bound when the chosen set carries no
    /// packing headroom).
    pub blind_rotations_packed: u64,
    /// Number of PBS-free linear ciphertext ops.
    pub linear_ops: u64,
    /// Worst multiplicative variance growth between two PBS (for the
    /// noise feasibility check): dominated by the longest plain-add chain.
    pub linear_growth: f64,
}

/// Signed bits to hold values in ±`mag`.
fn signed_bits_for_mag(mag: i64) -> u32 {
    crate::tensor::itensor::signed_bits_for(-mag, mag)
}

/// Unsigned bits to hold `[0, mag]`.
fn unsigned_bits_for_mag(mag: i64) -> u32 {
    crate::tensor::itensor::unsigned_bits_for(mag)
}

/// Worst-case analysis of the **Inhibitor** circuit (paper eqs. 5–6 with
/// the γ=√d literal requant and shift α):
///   diff → |diff| (PBS) → Σ_d (adds) → ÷γ (literal) → shifted ReLU (PBS)
///   → V−Z → ReLU (PBS) → Σ_T (adds) → requant (PBS per output).
pub fn profile_inhibitor(seq_len: usize, dim: usize, input_bits: u32) -> CircuitProfile {
    let t = seq_len as i64;
    let d = dim as i64;
    let in_mag = (1i64 << (input_bits - 1)) - 1; // symmetric codes
    // 1. q−k: signed, magnitude ≤ 2·in_mag.
    let diff_mag = 2 * in_mag;
    let mut int_bits = signed_bits_for_mag(diff_mag);
    // 2. |q−k| (PBS): unsigned ≤ diff_mag; Σ over d (adds): ≤ d·diff_mag.
    let dist_mag = d * diff_mag;
    // 3. ÷γ literal (γ=√d): magnitude shrinks by √d.
    let z_mag = ((dist_mag as f64) / (d as f64).sqrt()).ceil() as i64;
    // 4. shifted ReLU (PBS): still ≤ z_mag, unsigned.
    let mut uint_bits = unsigned_bits_for_mag(z_mag);
    // 5. v − z: signed, ≤ max(in_mag, z_mag) + min(...) ≤ in_mag + z_mag.
    let vz_mag = in_mag + z_mag;
    int_bits = int_bits.max(signed_bits_for_mag(vz_mag));
    // 6. ReLU (PBS) → unsigned ≤ vz_mag; Σ over T. The ReLU zeroes every
    //    inhibited term; calibrated circuits see ~√T effective mass, but
    //    worst case is T·in_mag (all scores zero, all values maximal).
    let h_mag = t * in_mag;
    uint_bits = uint_bits.max(unsigned_bits_for_mag(h_mag));
    // Op counts come from the circuit plan itself (α does not affect the
    // DAG shape): abs T²·d + shifted-relu T² + inhibition relu T²·d +
    // output requant T·d. The rewrite pipeline finds nothing to change
    // in this circuit, so the counts equal the raw plan's.
    let (pbs_count, blind_rotations_packed, linear_ops) =
        plan_counts(InhibitorFhe::new(dim, 1).plan(seq_len, dim));
    CircuitProfile {
        mechanism: Mechanism::Inhibitor,
        seq_len,
        dim,
        input_bits,
        int_bits,
        uint_bits,
        pbs_count,
        blind_rotations_packed,
        linear_ops,
        linear_growth: (t.max(d)) as f64,
    }
}

/// Worst-case analysis of the **signed Inhibitor** circuit (paper
/// eq. 7): the score path matches the unsigned head; the value path
/// splits V into V⁺/V⁻ (two LUTs of the same ciphertext — the
/// multi-value packing target) and inhibits both signs symmetrically.
/// Counts are read off the rewritten plan: the verbatim eq.-7 builder
/// emits `5T²d + T² + Td` LUT evaluations, CSE keeps `3T²d + T² + 3Td`,
/// and packing executes them in `3T²d + T² + 2Td` blind rotations.
pub fn profile_inhibitor_signed(seq_len: usize, dim: usize, input_bits: u32) -> CircuitProfile {
    let t = seq_len as i64;
    let d = dim as i64;
    let in_mag = (1i64 << (input_bits - 1)) - 1;
    let diff_mag = 2 * in_mag;
    let mut int_bits = signed_bits_for_mag(diff_mag);
    let dist_mag = d * diff_mag;
    let z_mag = ((dist_mag as f64) / (d as f64).sqrt()).ceil() as i64;
    let mut uint_bits = unsigned_bits_for_mag(z_mag);
    // v⁺ − z and v⁻ + z are both bounded by in_mag + z_mag in magnitude.
    let vz_mag = in_mag + z_mag;
    int_bits = int_bits.max(signed_bits_for_mag(vz_mag));
    // The signed accumulator mixes positive and negative terms; worst
    // case magnitude is T·in_mag on either side.
    let h_mag = t * in_mag;
    int_bits = int_bits.max(signed_bits_for_mag(h_mag));
    uint_bits = uint_bits.max(unsigned_bits_for_mag(h_mag));
    let (pbs_count, blind_rotations_packed, linear_ops) =
        plan_counts(InhibitorSignedFhe::new(dim, 1).plan(seq_len, dim));
    CircuitProfile {
        mechanism: Mechanism::InhibitorSigned,
        seq_len,
        dim,
        input_bits,
        int_bits,
        uint_bits,
        pbs_count,
        blind_rotations_packed,
        linear_ops,
        // The signed accumulator sums 2T PBS outputs (a positive and a
        // negative term per key position) before the output refresh —
        // twice the unsigned head's plain-add chain.
        linear_growth: ((2 * t).max(d)) as f64,
    }
}

/// Worst-case analysis of the **dot-product** circuit:
///   ct_mul(q,k) (2 PBS, needs q+k headroom) → Σ_d → exp LUT (PBS) →
///   Σ_T → recip (PBS) → ct_mul(e, r) (2 PBS) → ct_mul(p, v) (2 PBS) →
///   Σ_T → rescale (PBS).
pub fn profile_dotprod(seq_len: usize, dim: usize, input_bits: u32) -> CircuitProfile {
    let t = seq_len as i64;
    let d = dim as i64;
    let in_mag = (1i64 << (input_bits - 1)) - 1;
    // 1. ct_mul trick operands a±b: magnitude ≤ 2·in_mag ("up to two bits
    //    higher precision" — one bit here, one from the product below).
    let mut int_bits = signed_bits_for_mag(2 * in_mag);
    // 2. product: ≤ in_mag²; Σ over d: ≤ d·in_mag².
    let score_mag = d * in_mag * in_mag;
    int_bits = int_bits.max(signed_bits_for_mag(score_mag));
    // 3. exp LUT output: unsigned, table range [0, 2^e) with e chosen to
    //    preserve input resolution: e = input_bits + 1.
    let exp_mag = (1i64 << input_bits) - 1;
    // 4. Σ exp over T: ≤ T·exp_mag — the normalizer the recip LUT reads.
    let norm_mag = t * exp_mag;
    let mut uint_bits = unsigned_bits_for_mag(norm_mag);
    // 5. probs (recip-scaled): unsigned ≤ exp_mag; ct_mul(p,v): operands
    //    p+v ≤ exp_mag + in_mag, product ≤ exp_mag·in_mag — after the
    //    normalizing literal the row sums telescope to ≤ in_mag·(1+ε),
    //    but every PBS *input* must hold the raw product scale:
    let pv_mag = exp_mag + in_mag;
    int_bits = int_bits.max(signed_bits_for_mag(pv_mag));
    uint_bits = uint_bits.max(unsigned_bits_for_mag(exp_mag * in_mag / t.max(1)));
    // Op counts from the plan: ct_mul(q,k) 2·T²·d + exp T² + recip T +
    // ct_mul(e,r) 2·T² + ct_mul(p,v) 2·T²·d + rescale T·d. All PBS
    // inputs are distinct linear nodes, so the rewrites change nothing.
    let (pbs_count, blind_rotations_packed, linear_ops) =
        plan_counts(DotProductFhe::new(dim, in_mag).plan(seq_len, dim));
    CircuitProfile {
        mechanism: Mechanism::DotProduct,
        seq_len,
        dim,
        input_bits,
        int_bits,
        uint_bits,
        pbs_count,
        blind_rotations_packed,
        linear_ops,
        linear_growth: (t.max(d)) as f64,
    }
}

/// Profile dispatcher.
pub fn profile(mech: Mechanism, seq_len: usize, dim: usize, input_bits: u32) -> CircuitProfile {
    match mech {
        Mechanism::DotProduct => profile_dotprod(seq_len, dim, input_bits),
        Mechanism::Inhibitor => profile_inhibitor(seq_len, dim, input_bits),
        Mechanism::InhibitorSigned => profile_inhibitor_signed(seq_len, dim, input_bits),
    }
}

/// Static profile of an H-head **fused** attention plan
/// (`fhe_circuits::MultiHeadFhe`): the per-head widths are those of the
/// constituent single head (each head sees only its own `d_head`-wide
/// slice, so precision requirements do not grow with H), while the
/// closed-form op counts account for cross-head CSE and packing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MultiHeadProfile {
    /// The constituent single-head profile.
    pub head: CircuitProfile,
    pub n_heads: usize,
    /// Multi-query layout (one K/V segment shared by all heads).
    pub shared_kv: bool,
    /// LUT evaluations of the fused H-head plan (after CSE) — what the
    /// serving path executes on any parameter set.
    pub pbs_count: u64,
    /// Blind rotations of the fused plan at a packing budget of 2^ϑ ≥ 2.
    pub blind_rotations_packed: u64,
}

/// Closed-form multi-head counts, checked against the fused plan's own
/// `pbs_count()`/`blind_rotation_count()` oracles by a unit test so the
/// formulas can never drift from the IR. Cross-head sharing exists only
/// in the shared-KV signed circuit: every head re-emits the V⁺/V⁻
/// splits of the *same* value ciphertexts, so CSE keeps one split pair
/// per value for the whole block (2·(H−1)·T·d fewer LUT evaluations
/// than H separate circuits) and packing executes the survivors in T·d
/// rotations instead of H·T·d ((H−1)·T·d fewer). All other
/// configurations are exactly H× the single-head closed forms — the H
/// subgraphs are disjoint.
pub fn profile_multihead(
    mech: Mechanism,
    seq_len: usize,
    d_head: usize,
    n_heads: usize,
    shared_kv: bool,
    input_bits: u32,
) -> MultiHeadProfile {
    assert!(n_heads >= 1);
    let head = profile(mech, seq_len, d_head, input_bits);
    let h = n_heads as u64;
    let (t, d) = (seq_len as u64, d_head as u64);
    let (dup_luts, dup_rots) = if shared_kv && mech == Mechanism::InhibitorSigned {
        (2 * (h - 1) * t * d, (h - 1) * t * d)
    } else {
        (0, 0)
    };
    MultiHeadProfile {
        head,
        n_heads,
        shared_kv,
        pbs_count: h * head.pbs_count - dup_luts,
        blind_rotations_packed: h * head.blind_rotations_packed - dup_rots,
    }
}

impl CircuitProfile {
    /// Message bits the parameter set must carry (max over signed and
    /// unsigned requirements; our encoding holds signed p-bit values in a
    /// p-bit biased space).
    pub fn required_message_bits(&self) -> u32 {
        self.int_bits.max(self.uint_bits)
    }
}

/// Static profile of a fused L-layer transformer-block plan
/// (`fhe_circuits::ModelFhe`): closed-form LUT-evaluation and
/// blind-rotation counts at a given packing budget, checked against the
/// plan's own `pbs_count()`/`blind_rotation_count()` oracles by a unit
/// test so the formulas can never drift from the IR.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockProfile {
    pub mechanism: Mechanism,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub ffn_dim: usize,
    pub shared_kv: bool,
    /// The packing budget the rotation figure assumes (1 = packing off).
    pub max_multi_lut: usize,
    /// LUT evaluations of one forward pass (after the always-safe CSE
    /// pass — what the serving path executes on any parameter set).
    pub pbs_count: u64,
    /// Blind rotations of one forward pass at the given budget.
    pub blind_rotations: u64,
    /// PBS execution levels of the stacked plan.
    pub levels: u64,
}

/// Closed-form counts of the fused L-layer block plan. Per layer:
///
/// * attention per head — the standard closed forms (the signed head's
///   value splits are emitted once per value by the block builder, so
///   its per-head body is the CSE'd `3T²d + T² + Td` plus a separate
///   `2·T·d_kv` split term shared across heads under `shared_kv`);
/// * dot-product heads attend the residual stream with q = k, so the
///   eq.-1 sum-half of the (i,j)/(j,i) score products is symmetric and
///   CSE merges `d·T(T−1)/2` square LUTs per q==k head (every head with
///   per-head KV; only head 0 under `shared_kv`);
/// * block tail — W_O requant `T·D`, two residual requants `2·T·D`,
///   fc2 requant `T·D` and the fused fc1 requant+ReLU `T·F`.
///
/// Rotations subtract the packed groups: the layer-0 value-split pairs
/// (1 rotation saved per value at any budget ≥ 2) and, per stacked
/// boundary, the requant + ReLU-split + negative-split **trio** on the
/// previous layer's residual accumulator (1 saved at a budget of 2,
/// 2 saved — one rotation for all three tables — at ϑ ≥ 2). Both exist
/// only for the signed mechanism. The forms assume weight matrices with
/// pairwise-distinct (row, bias) pairs (`BlockWeights::demo` guarantees
/// it); duplicate rows would CSE further.
#[allow(clippy::too_many_arguments)]
pub fn profile_block(
    mech: Mechanism,
    seq_len: usize,
    d_model: usize,
    n_heads: usize,
    n_layers: usize,
    ffn_dim: usize,
    shared_kv: bool,
    max_multi_lut: usize,
) -> BlockProfile {
    assert!(n_layers >= 1, "a block profile needs at least one layer");
    let split = HeadSplit::new(d_model, n_heads);
    let (t, dm, h, f, l) =
        (seq_len as u64, d_model as u64, n_heads as u64, ffn_dim as u64, n_layers as u64);
    let d = split.d_head() as u64;
    let attn_per_head = match mech {
        Mechanism::Inhibitor => 2 * t * t * d + t * t + t * d,
        Mechanism::InhibitorSigned => 3 * t * t * d + t * t + t * d,
        Mechanism::DotProduct => 4 * t * t * d + 3 * t * t + t + t * d,
    };
    let vcols = if shared_kv { d } else { dm };
    let splits = if mech == Mechanism::InhibitorSigned { 2 * t * vcols } else { 0 };
    let dup = if mech == Mechanism::DotProduct {
        let merged_heads = if shared_kv { 1 } else { h };
        merged_heads * d * t * (t - 1) / 2
    } else {
        0
    };
    let per_layer = h * attn_per_head + splits - dup + 4 * t * dm + t * f;
    let pbs_count = l * per_layer;
    let saved = if mech == Mechanism::InhibitorSigned {
        let nv = t * vcols;
        let sv_pair: u64 = if max_multi_lut >= 2 { 1 } else { 0 };
        let sv_trio: u64 = match max_multi_lut {
            0 | 1 => 0,
            2 => 1,
            _ => 2,
        };
        nv * sv_pair + (l - 1) * nv * sv_trio
    } else {
        0
    };
    let per_layer_levels: u64 = if mech == Mechanism::DotProduct { 11 } else { 9 };
    BlockProfile {
        mechanism: mech,
        seq_len,
        d_model,
        n_heads,
        n_layers,
        ffn_dim,
        shared_kv,
        max_multi_lut,
        pbs_count,
        blind_rotations: pbs_count - saved,
        levels: l * per_layer_levels,
    }
}

/// Static profile of one incremental-decode **step** plan
/// (`fhe_circuits::DecodeFhe::step_plan`) — or, via [`profile_prefill`],
/// of the causal prefill plan, which is exactly the per-prefix step sum.
/// Checked against the plan's own oracles by a unit test so the forms
/// can never drift from the IR.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepProfile {
    pub mechanism: Mechanism,
    /// Positions already cached (the step attends `cached_len + 1`
    /// positions). For a prefill profile: the prefill length `T`.
    pub cached_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub ffn_dim: usize,
    pub shared_kv: bool,
    /// The packing budget the rotation figure assumes (1 = packing off).
    pub max_multi_lut: usize,
    /// LUT evaluations (after the always-safe CSE pass).
    pub pbs_count: u64,
    /// Blind rotations at the given budget.
    pub blind_rotations: u64,
    /// PBS execution levels.
    pub levels: u64,
}

/// Closed-form counts of one decode step at prefix `cached_len`
/// (`n = cached_len + 1` attended positions). Per layer:
///
/// * attention per head, one query row against `n` positions —
///   inhibitor `2nd + n + d`, signed (pre-split values) `3nd + n + d`,
///   dot-product `4nd + 3n + 1 + d`. Strictly **linear** in the prefix
///   length: the T² term of the full circuits is gone, which a unit
///   test pins by checking the per-step delta is constant in `t`;
/// * signed mechanisms add `2·vcols` fresh split PBS for the *new*
///   position only (every cached split arrives as a plan input);
/// * block tail — W_O requant `D`, two residual requants `2·D`, fc2
///   requant `D`, fused fc1 requant+ReLU `F`.
///
/// No CSE term exists: the step emitters produce no duplicate PBS
/// (causal ordering admits no transposed dot-product pairs, and splits
/// are emitted once by construction). Rotations subtract the same
/// signed packing groups as [`profile_block`], per token: the layer-0
/// relu/min0 pair on the new input row (`vcols` at budget ≥ 2) and the
/// requant/split trio on each stacked boundary's accumulator row
/// (`(L−1)·vcols`, 1 saved at a budget of 2, 2 at ϑ ≥ 2).
#[allow(clippy::too_many_arguments)]
pub fn profile_step(
    mech: Mechanism,
    cached_len: usize,
    d_model: usize,
    n_heads: usize,
    n_layers: usize,
    ffn_dim: usize,
    shared_kv: bool,
    max_multi_lut: usize,
) -> StepProfile {
    assert!(n_layers >= 1, "a step profile needs at least one layer");
    let split = HeadSplit::new(d_model, n_heads);
    let (n, dm, h, f, l) = (
        cached_len as u64 + 1,
        d_model as u64,
        n_heads as u64,
        ffn_dim as u64,
        n_layers as u64,
    );
    let d = split.d_head() as u64;
    let attn_per_head = match mech {
        Mechanism::Inhibitor => 2 * n * d + n + d,
        Mechanism::InhibitorSigned => 3 * n * d + n + d,
        Mechanism::DotProduct => 4 * n * d + 3 * n + 1 + d,
    };
    let vcols = if shared_kv { d } else { dm };
    let splits_new = if mech == Mechanism::InhibitorSigned { 2 * vcols } else { 0 };
    let per_layer = h * attn_per_head + splits_new + 4 * dm + f;
    let pbs_count = l * per_layer;
    let saved = step_packing_saved(mech, vcols, l, max_multi_lut);
    let per_layer_levels: u64 = if mech == Mechanism::DotProduct { 11 } else { 9 };
    StepProfile {
        mechanism: mech,
        cached_len,
        d_model,
        n_heads,
        n_layers,
        ffn_dim,
        shared_kv,
        max_multi_lut,
        pbs_count,
        blind_rotations: pbs_count - saved,
        levels: l * per_layer_levels,
    }
}

/// Rotations one decode token saves to packing (signed mechanism only;
/// independent of the prefix length — the groups sit on the *new*
/// row's nodes).
fn step_packing_saved(mech: Mechanism, vcols: u64, n_layers: u64, max_multi_lut: usize) -> u64 {
    if mech != Mechanism::InhibitorSigned {
        return 0;
    }
    let sv_pair: u64 = if max_multi_lut >= 2 { 1 } else { 0 };
    let sv_trio: u64 = match max_multi_lut {
        0 | 1 => 0,
        2 => 1,
        _ => 2,
    };
    vcols * sv_pair + (n_layers - 1) * vcols * sv_trio
}

/// Closed-form counts of the causal prefill plan for `seq_len` tokens
/// (`fhe_circuits::DecodeFhe::prefill_plan`): exactly the sum of
/// [`profile_step`] over prefixes `0..seq_len` — the prefill *is* the
/// step recurrence looped, per-call LUT registration prevents any
/// cross-token CSE, and causal ordering admits no transposed product
/// pairs — with the level depth staying `L·(9|11)` (layer-0 work of any
/// token depends only on plan inputs, so token index adds no depth).
/// Also pinned against the plan oracles.
#[allow(clippy::too_many_arguments)]
pub fn profile_prefill(
    mech: Mechanism,
    seq_len: usize,
    d_model: usize,
    n_heads: usize,
    n_layers: usize,
    ffn_dim: usize,
    shared_kv: bool,
    max_multi_lut: usize,
) -> StepProfile {
    assert!(seq_len >= 1, "a prefill profile needs at least one token");
    let mut pbs_count = 0u64;
    let mut blind_rotations = 0u64;
    let mut levels = 0u64;
    for t in 0..seq_len {
        let s = profile_step(mech, t, d_model, n_heads, n_layers, ffn_dim, shared_kv, max_multi_lut);
        pbs_count += s.pbs_count;
        blind_rotations += s.blind_rotations;
        levels = s.levels;
    }
    StepProfile {
        mechanism: mech,
        cached_len: seq_len,
        d_model,
        n_heads,
        n_layers,
        ffn_dim,
        shared_kv,
        max_multi_lut,
        pbs_count,
        blind_rotations,
        levels,
    }
}

/// Static profile of the radix legalization pass (`tfhe::plan`, see
/// rust/DESIGN.md §10) on the canonical accumulator shape: a sum of
/// `n_terms` bootstrap outputs declared wider than the native message
/// space and split onto `spec`. Checked against the legalized plan's own
/// `pbs_count()`/`blind_rotation_count()` oracles and the rewriter's
/// carry counters by a unit test so the forms can never drift from the
/// legalizer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RadixProfile {
    /// Limb shape the pass legalizes against.
    pub spec: RadixSpec,
    /// Narrow bootstrap outputs feeding the wide accumulator.
    pub n_terms: usize,
    /// The packing budget the rotation figures assume (1 = packing off).
    pub max_multi_lut: usize,
    /// Digit-decomposition LUT evaluations: `span` same-input tables per
    /// narrow source entering the wide domain.
    pub decomp_pbs: u64,
    /// Blind rotations of the decomposition groups: ⌈span/budget⌉ per
    /// source once packing fuses the same-input digit tables.
    pub decomp_rotations: u64,
    /// Carry-propagation ripples the capacity discipline forces: one per
    /// accumulator overflow during the fold, plus the output ripple
    /// whenever the result is not already canonical.
    pub canons: u64,
    /// Message/carry/top-wrap LUT evaluations: `2k − 1` per ripple.
    pub carry_pbs: u64,
    /// Rotations of those ripples: the message and carry tables of one
    /// limb share a rotation at budget ≥ 2, the top wrap stands alone.
    pub carry_rotations: u64,
    /// Total LUT evaluations the legalization adds to the narrow plan.
    pub pbs: u64,
    /// Total blind rotations the legalization adds.
    pub blind_rotations: u64,
}

/// Closed-form radix-legalization counts: the exact bound-bookkeeping
/// simulation of the legalizer's left-first `Sum` fold. Each term enters
/// the wide domain with limbs bounded by `digit_max`; the running
/// accumulator ripples whenever the next add could push a limb past
/// `add_cap`, and once more at the output unless a lone term's
/// decomposition already fills every limb (span = k).
pub fn profile_radix(n_terms: usize, spec: RadixSpec, max_multi_lut: usize) -> RadixProfile {
    assert!(n_terms >= 1, "a radix profile needs at least one term");
    let budget = max_multi_lut.max(1) as u64;
    let (k, span) = (spec.limbs as u64, spec.span() as u64);
    let (dm, cap) = (spec.digit_max(), spec.add_cap());
    let n = n_terms as u64;
    let decomp_pbs = n * span;
    let decomp_rotations = n * span.div_ceil(budget);
    let mut canons = 0u64;
    let mut bound = dm;
    let mut canonical = span == k;
    for _ in 1..n_terms {
        if bound + dm > cap {
            canons += 1;
            bound = dm;
        }
        bound += dm;
        canonical = false;
    }
    if !canonical {
        canons += 1;
    }
    let carry_pbs = canons * (2 * k - 1);
    let per_ripple = (k - 1) * if budget >= 2 { 1 } else { 2 } + 1;
    RadixProfile {
        spec,
        n_terms,
        max_multi_lut,
        decomp_pbs,
        decomp_rotations,
        canons,
        carry_pbs,
        carry_rotations: canons * per_ripple,
        pbs: decomp_pbs + carry_pbs,
        blind_rotations: decomp_rotations + canons * per_ripple,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dotprod_needs_more_precision_than_inhibitor() {
        // The paper's Table 2 headline: 1–2 extra bits for dot-product.
        for t in [2usize, 4, 8, 16] {
            let inh = profile_inhibitor(t, 2, 3);
            let dot = profile_dotprod(t, 2, 3);
            assert!(
                dot.required_message_bits() > inh.required_message_bits(),
                "T={t}: dot {} vs inh {}",
                dot.required_message_bits(),
                inh.required_message_bits()
            );
        }
    }

    #[test]
    fn dotprod_needs_about_twice_the_pbs() {
        for t in [2usize, 4, 8, 16] {
            let inh = profile_inhibitor(t, 2, 3);
            let dot = profile_dotprod(t, 2, 3);
            let ratio = dot.pbs_count as f64 / inh.pbs_count as f64;
            assert!((1.5..=2.5).contains(&ratio), "T={t}: ratio {ratio}");
        }
    }

    #[test]
    fn uint_grows_with_sequence_length() {
        let a = profile_inhibitor(2, 2, 3);
        let b = profile_inhibitor(16, 2, 3);
        assert!(b.uint_bits > a.uint_bits);
        let c = profile_dotprod(2, 2, 3);
        let d = profile_dotprod(16, 2, 3);
        assert!(d.uint_bits > c.uint_bits);
    }

    #[test]
    fn signed_profile_reads_rewritten_counts() {
        let (t, d) = (4u64, 2u64);
        let p = profile_inhibitor_signed(4, 2, 3);
        assert_eq!(p.pbs_count, 3 * t * t * d + t * t + 3 * t * d, "CSE'd LUT evals");
        assert_eq!(
            p.blind_rotations_packed,
            3 * t * t * d + t * t + 2 * t * d,
            "packed rotations"
        );
        assert!(p.blind_rotations_packed < p.pbs_count);
        assert_eq!(profile(Mechanism::InhibitorSigned, 4, 2, 3).pbs_count, p.pbs_count);
        // Circuits the packing pass leaves untouched report equality.
        let u = profile_inhibitor(4, 2, 3);
        assert_eq!(u.blind_rotations_packed, u.pbs_count);
        let q = profile_dotprod(4, 2, 3);
        assert_eq!(q.blind_rotations_packed, q.pbs_count);
    }

    #[test]
    fn multihead_profile_matches_the_fused_plan_oracles() {
        // The closed forms must reproduce what the fused H-head plan
        // actually counts after the same rewrite configurations the
        // single-head profile uses (CSE for LUT evaluations, CSE +
        // budget-2 packing for rotations) — for every mechanism, both
        // KV layouts, H = 1..3.
        use crate::fhe_circuits::MultiHeadFhe;
        use crate::tfhe::plan::{PlanRewriter, RewriteConfig};
        let (t, d) = (3usize, 2usize);
        for &mech in &[Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            for &(heads, shared) in &[(1usize, false), (2, false), (2, true), (3, true)] {
                let mh = MultiHeadFhe::new(mech, d, heads, shared);
                let (cse, _) = PlanRewriter::new(RewriteConfig::cse_only()).rewrite(mh.plan(t, d));
                let (packed, _) = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 2 })
                    .rewrite(mh.plan(t, d));
                let p = profile_multihead(mech, t, d, heads, shared, 3);
                let tag = format!("{mech:?} H={heads} shared={shared}");
                assert_eq!(p.pbs_count, cse.pbs_count(), "{tag}: LUT evals");
                assert_eq!(
                    p.blind_rotations_packed,
                    packed.blind_rotation_count(),
                    "{tag}: rotations"
                );
                assert_eq!(p.head.pbs_count, profile(mech, t, d, 3).pbs_count);
            }
        }
        // The cross-head win is visible in the profile itself: shared-KV
        // signed needs strictly fewer rotations than H disjoint heads.
        let fused = profile_multihead(Mechanism::InhibitorSigned, t, d, 3, true, 3);
        let disjoint = profile_multihead(Mechanism::InhibitorSigned, t, d, 3, false, 3);
        assert!(fused.blind_rotations_packed < disjoint.blind_rotations_packed);
        assert!(fused.pbs_count < disjoint.pbs_count);
    }

    #[test]
    fn block_profile_matches_the_fused_plan_oracles() {
        // The closed forms must reproduce what the fused L-layer block
        // plan actually counts after the same rewrite configurations the
        // other profiles use (CSE for LUT evaluations; CSE + packing at
        // budgets 1, 2 and 4 for rotations) — for every mechanism, both
        // KV layouts, one and two layers. Pure DAG analysis, no crypto.
        use crate::fhe_circuits::ModelFhe;
        use crate::tfhe::plan::{PlanRewriter, RewriteConfig};
        for &mech in &[Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            for &(heads, layers, t, d, shared) in &[
                (1usize, 1usize, 2usize, 2usize, false),
                (2, 1, 3, 2, false),
                (2, 2, 2, 1, false),
                (2, 2, 2, 2, true),
                (1, 2, 2, 2, false),
            ] {
                let dm = heads * d;
                let ffn = 2 * dm;
                let model = ModelFhe::demo(mech, dm, heads, layers, shared, ffn, 0xB10C7);
                let tag = format!("{mech:?} H={heads} L={layers} T={t} d={d} shared={shared}");
                let (cse, _) =
                    PlanRewriter::new(RewriteConfig::cse_only()).rewrite(model.plan(t));
                for budget in [1usize, 2, 4] {
                    let p = profile_block(mech, t, dm, heads, layers, ffn, shared, budget);
                    assert_eq!(p.pbs_count, cse.pbs_count(), "{tag}: LUT evals");
                    assert_eq!(p.levels, cse.levels() as u64, "{tag}: levels");
                    let (packed, _) =
                        PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: budget })
                            .rewrite(model.plan(t));
                    assert_eq!(
                        p.blind_rotations,
                        packed.blind_rotation_count(),
                        "{tag}: rotations at budget {budget}"
                    );
                    assert_eq!(packed.pbs_count(), p.pbs_count, "{tag}: packing keeps evals");
                }
            }
        }
        // The cross-layer win is visible in the profile itself: at ϑ ≥ 2
        // a stacked signed L=2 plan needs strictly fewer rotations than
        // at ϑ = 1, by exactly one extra saving per folded trio.
        let theta1 = profile_block(Mechanism::InhibitorSigned, 2, 4, 2, 2, 4, false, 2);
        let theta2 = profile_block(Mechanism::InhibitorSigned, 2, 4, 2, 2, 4, false, 4);
        assert_eq!(theta1.pbs_count, theta2.pbs_count);
        assert_eq!(
            theta1.blind_rotations - theta2.blind_rotations,
            2 * 4, // (L−1) · T · d_model trios, one extra rotation each
        );
    }

    #[test]
    fn step_profile_matches_the_decode_plan_oracles() {
        // The per-step closed forms must reproduce what the decode step
        // plan actually counts after the same rewrite configurations the
        // block profile uses — for every mechanism, both KV layouts,
        // several prefix lengths. Pure DAG analysis, no crypto.
        use crate::fhe_circuits::{DecodeFhe, ModelFhe};
        use crate::tfhe::plan::{PlanRewriter, RewriteConfig};
        for &mech in &[Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            for &(heads, layers, t, d, shared) in &[
                (1usize, 1usize, 0usize, 2usize, false),
                (2, 1, 1, 2, false),
                (2, 2, 2, 1, false),
                (2, 2, 1, 2, true),
                (1, 2, 3, 2, false),
            ] {
                let dm = heads * d;
                let ffn = 2 * dm;
                let dec = DecodeFhe::new(ModelFhe::demo(mech, dm, heads, layers, shared, ffn, 0xDEC3));
                let tag = format!("{mech:?} H={heads} L={layers} t={t} d={d} shared={shared}");
                let (cse, _) =
                    PlanRewriter::new(RewriteConfig::cse_only()).rewrite(dec.step_plan(t));
                for budget in [1usize, 2, 4] {
                    let p = profile_step(mech, t, dm, heads, layers, ffn, shared, budget);
                    assert_eq!(p.pbs_count, cse.pbs_count(), "{tag}: LUT evals");
                    assert_eq!(p.levels, cse.levels() as u64, "{tag}: levels");
                    let (packed, _) =
                        PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: budget })
                            .rewrite(dec.step_plan(t));
                    assert_eq!(
                        p.blind_rotations,
                        packed.blind_rotation_count(),
                        "{tag}: rotations at budget {budget}"
                    );
                }
            }
        }
    }

    #[test]
    fn step_cost_is_linear_in_prefix_length() {
        // The whole point of the decode subsystem: the per-step delta is
        // CONSTANT in t (no T² term), and strictly below the full
        // recompute's delta already at small prefixes.
        for &mech in &[Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            let p = |t| profile_step(mech, t, 4, 2, 2, 8, false, 2);
            let delta = p(1).pbs_count - p(0).pbs_count;
            for t in 1..8 {
                assert_eq!(
                    p(t + 1).pbs_count - p(t).pbs_count,
                    delta,
                    "{mech:?}: per-step delta must be constant in t"
                );
            }
            // Full recompute at T grows quadratically; the step at the
            // same prefix stays linear.
            let full = profile_block(mech, 8, 4, 2, 2, 8, false, 2);
            let step = p(7);
            assert!(step.pbs_count < full.pbs_count, "{mech:?}: step beats recompute");
        }
    }

    #[test]
    fn prefill_profile_is_the_step_sum_and_matches_the_plan_oracles() {
        use crate::fhe_circuits::{DecodeFhe, ModelFhe};
        use crate::tfhe::plan::{PlanRewriter, RewriteConfig};
        for &mech in &[Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
            for &(heads, layers, t, shared) in
                &[(1usize, 1usize, 2usize, false), (2, 2, 3, false), (2, 2, 2, true)]
            {
                let dm = 2 * heads;
                let ffn = 2 * dm;
                let dec = DecodeFhe::new(ModelFhe::demo(mech, dm, heads, layers, shared, ffn, 0xDEC4));
                let tag = format!("{mech:?} H={heads} L={layers} T={t} shared={shared}");
                let (cse, _) =
                    PlanRewriter::new(RewriteConfig::cse_only()).rewrite(dec.prefill_plan(t));
                for budget in [1usize, 2] {
                    let p = profile_prefill(mech, t, dm, heads, layers, ffn, shared, budget);
                    // The sum identity, independent of the oracles.
                    let sum: u64 = (0..t)
                        .map(|i| {
                            profile_step(mech, i, dm, heads, layers, ffn, shared, budget).pbs_count
                        })
                        .sum();
                    assert_eq!(p.pbs_count, sum, "{tag}: prefill = Σ steps");
                    assert_eq!(p.pbs_count, cse.pbs_count(), "{tag}: LUT evals");
                    assert_eq!(p.levels, cse.levels() as u64, "{tag}: levels");
                    let (packed, _) =
                        PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: budget })
                            .rewrite(dec.prefill_plan(t));
                    assert_eq!(
                        p.blind_rotations,
                        packed.blind_rotation_count(),
                        "{tag}: rotations at budget {budget}"
                    );
                }
            }
        }
    }

    #[test]
    fn radix_profile_matches_the_legalized_plan_oracles() {
        // The closed forms must reproduce what the legalized plan
        // actually counts on the canonical accumulator shape — n
        // distinct-LUT bootstraps feeding one wide-declared Sum — for
        // every limb grid spec, several term counts, and the same
        // budgets the other profiles sweep. Pure DAG analysis, no
        // crypto: the narrow plan costs exactly n LUT evaluations and n
        // rotations, so the legalization delta is the whole difference.
        use crate::tfhe::plan::CircuitBuilder;
        use crate::tfhe::radix::RadixConfig;
        for &(w, native, declared) in &[(5u32, 8u32, 10u32), (3, 6, 9), (2, 6, 8), (1, 4, 6)] {
            let cfg = RadixConfig::new(native).with_limb_bits(w);
            let spec = cfg.spec_for(declared).unwrap();
            for n in [1usize, 2, 3, 7] {
                let build = || {
                    let mut b = CircuitBuilder::new();
                    let xs = b.inputs(n);
                    let terms: Vec<_> = xs
                        .iter()
                        .enumerate()
                        .map(|(i, &x)| {
                            let lut = b.lut(move |v| v + i as i64);
                            b.pbs(x, lut)
                        })
                        .collect();
                    let s = b.sum(&terms);
                    b.output(s);
                    b.declare_width(s, declared);
                    b.build()
                };
                for budget in [1usize, 2, 4] {
                    let p = profile_radix(n, spec, budget);
                    let (plan, stats) =
                        PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: budget })
                            .with_radix(cfg)
                            .rewrite(build());
                    let tag = format!("w={w} native={native} n={n} budget={budget}");
                    assert_eq!(plan.pbs_count(), n as u64 + p.pbs, "{tag}: LUT evals");
                    assert_eq!(
                        plan.blind_rotation_count(),
                        n as u64 + p.blind_rotations,
                        "{tag}: rotations"
                    );
                    assert_eq!(stats.carry_luts, p.carry_pbs, "{tag}: carry LUTs");
                    assert_eq!(stats.carry_rotations, p.carry_rotations, "{tag}: carry rots");
                    assert_eq!(p.pbs, p.decomp_pbs + p.carry_pbs);
                }
            }
        }
        // The capacity discipline is visible in the profile itself: a
        // long fold at a cramped native space ripples strictly more
        // often than the same fold with generous limb headroom.
        let cramped = profile_radix(16, RadixSpec::new(1, 6, 4), 2);
        let roomy = profile_radix(16, RadixSpec::new(3, 3, 8), 2);
        assert!(cramped.canons > roomy.canons, "{cramped:?} vs {roomy:?}");
        // Packing pays off: budget ≥ 2 needs strictly fewer rotations
        // than unpacked execution of the same legalized plan.
        let unpacked = profile_radix(4, RadixSpec::new(2, 4, 6), 1);
        let packed = profile_radix(4, RadixSpec::new(2, 4, 6), 2);
        assert_eq!(unpacked.pbs, packed.pbs);
        assert!(packed.blind_rotations < unpacked.blind_rotations);
    }

    #[test]
    fn pbs_counts_match_closed_forms() {
        // The plan-derived counts must reproduce the paper's closed-form
        // per-head formulas (T=4, d=2): inhibitor 2·T²·d + T² + T·d and
        // dot-product 4·T²·d + 3·T² + T + T·d.
        let p = profile_inhibitor(4, 2, 3);
        assert_eq!(p.pbs_count, 2 * 16 * 2 + 16 + 8);
        let q = profile_dotprod(4, 2, 3);
        assert_eq!(q.pbs_count, 4 * 16 * 2 + 3 * 16 + 4 + 8);
    }
}
