//! TFHE cost model (S7): estimate the execution cost of a parameter set
//! and of whole circuits in PBS-equivalents and in (calibrated) seconds.
//!
//! The dominant cost is the blind rotation: `n` CMux, each one external
//! product = `(k+1)·ℓ` forward FFTs + `(k+1)` inverse FFTs of size N/2
//! plus `(k+1)²·ℓ` pointwise multiply-accumulates. Key switching adds
//! `k·N·ℓ_ks` scaled vector subtractions of length `n`.

use crate::tfhe::params::TfheParams;

/// Abstract cost unit: weighted floating-point-op count. Convert to
/// seconds with a per-host calibration factor (measured by
/// `calibrate_flops_per_sec` or the `pbs_microbench` bench).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cost(pub f64);

impl Cost {
    pub fn seconds(&self, flops_per_sec: f64) -> f64 {
        self.0 / flops_per_sec
    }
}

/// FFT cost in flops: ~5·m·log2(m) for size m (radix-2, complex).
fn fft_flops(m: usize) -> f64 {
    let mf = m as f64;
    5.0 * mf * mf.log2().max(1.0)
}

/// Estimated flops of one programmable bootstrap under `p`.
pub fn pbs_cost(p: &TfheParams) -> Cost {
    let n = p.lwe_dim as f64;
    let k = p.glwe_dim as f64;
    let l = p.pbs_decomp.level as f64;
    let half = (p.poly_size / 2).max(1);
    // Per CMux: (k+1)·ℓ forward + (k+1) inverse FFTs, (k+1)²·ℓ pointwise
    // MACs (6 flops each), (k+1)·2 poly rotations/adds (2 flops per coeff),
    // and the decomposition pass ((k+1)·ℓ·N integer ops ≈ 1 flop each).
    let per_cmux = ((k + 1.0) * l + (k + 1.0)) * fft_flops(half)
        + (k + 1.0) * (k + 1.0) * l * 6.0 * half as f64
        + (k + 1.0) * 2.0 * 2.0 * p.poly_size as f64
        + (k + 1.0) * l * p.poly_size as f64;
    // Key switch: k·N rows × ℓ_ks digits × (n+1) fused mul-subs.
    let ks = (p.extracted_lwe_dim() as f64)
        * (p.ks_decomp.level as f64)
        * (p.lwe_dim as f64 + 1.0)
        * 2.0;
    Cost(n * per_cmux + ks)
}

/// Cost of a linear (no-PBS) homomorphic op: one length-(n+1) vector pass.
pub fn linear_op_cost(p: &TfheParams) -> Cost {
    Cost((p.lwe_dim + 1) as f64)
}

/// Circuit-level cost: `n_pbs` bootstraps + `n_linear` linear ops.
pub fn circuit_cost(p: &TfheParams, n_pbs: u64, n_linear: u64) -> Cost {
    Cost(pbs_cost(p).0 * n_pbs as f64 + linear_op_cost(p).0 * n_linear as f64)
}

/// Measure this host's effective flops/sec on an FFT-shaped workload by
/// timing real PBS executions (used by benches to convert model costs to
/// projected seconds; returns flops/sec).
pub fn calibrate_flops_per_sec(measured_pbs_seconds: f64, p: &TfheParams) -> f64 {
    pbs_cost(p).0 / measured_pbs_seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::params::DecompParams;

    #[test]
    fn cost_grows_with_poly_size() {
        let mut a = TfheParams::test_small();
        let mut b = a;
        a.poly_size = 1024;
        b.poly_size = 4096;
        assert!(pbs_cost(&b).0 > 3.0 * pbs_cost(&a).0);
    }

    #[test]
    fn cost_grows_with_level_and_dim() {
        let base = TfheParams::test_small();
        let mut lvl2 = base;
        lvl2.pbs_decomp = DecompParams::new(8, 4);
        assert!(pbs_cost(&lvl2).0 > pbs_cost(&base).0);
        let mut bigger_n = base;
        bigger_n.lwe_dim = 2 * base.lwe_dim;
        assert!(pbs_cost(&bigger_n).0 > 1.9 * pbs_cost(&base).0);
    }

    #[test]
    fn linear_ops_are_orders_cheaper_than_pbs() {
        let p = TfheParams::test_small();
        assert!(pbs_cost(&p).0 / linear_op_cost(&p).0 > 1e4);
    }

    #[test]
    fn seconds_conversion() {
        let p = TfheParams::test_small();
        let c = pbs_cost(&p);
        let fps = calibrate_flops_per_sec(0.01, &p);
        assert!((c.seconds(fps) - 0.01).abs() < 1e-12);
    }
}
