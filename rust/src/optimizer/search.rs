//! TFHE parameter search (S7), after Bergerat et al. 2023: pick the
//! cheapest (macro, micro) parameter combination that satisfies the noise
//! constraint for a circuit profile at a target security level and
//! failure probability. Regenerates the paper's Table 2.

use super::cost::{circuit_cost, pbs_cost};
use super::noise::{min_noise_for_security, params_feasible};
use super::precision::CircuitProfile;
use crate::tfhe::params::{DecompParams, TfheParams};

/// Search configuration.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    pub security: u32,
    /// Per-PBS decode failure target (Concrete's default class: ~2^-13.9;
    /// we default tighter because attention circuits chain many PBS).
    pub p_fail: f64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        // 2^-13.9 is Concrete's default per-PBS failure class, which the
        // paper's Table 2 parameters were selected under.
        SearchConfig { security: 128, p_fail: 2f64.powf(-13.9) }
    }
}

/// Result of an optimization run.
#[derive(Clone, Debug)]
pub struct OptimizedParams {
    pub params: TfheParams,
    /// Model cost of one circuit execution (flop-equivalents).
    pub circuit_flops: f64,
    pub profile: CircuitProfile,
}

/// Exhaustive search over the macro/micro grid. The grid mirrors the
/// ranges Concrete explores: N ∈ {1024..8192}, k=1, ℓ ∈ {1,2,3},
/// baseLog ∈ {5..25}, n ∈ {500..1000}.
pub fn optimize(profile: &CircuitProfile, cfg: SearchConfig) -> Option<OptimizedParams> {
    let msg_bits = profile.required_message_bits();
    let mut best: Option<(f64, TfheParams)> = None;
    for poly_log in 10..=13u32 {
        let poly_size = 1usize << poly_log;
        if poly_size < (1usize << (msg_bits + 1)) {
            continue; // blind rotation cannot resolve the slots
        }
        let glwe_noise = min_noise_for_security(poly_size, cfg.security);
        for level in 1..=3usize {
            for base_log in 5..=25usize {
                if base_log * level > 53 {
                    continue; // beyond f64-FFT-safe digit mass
                }
                for ks in [
                    DecompParams::new(4, 4),
                    DecompParams::new(4, 6),
                    DecompParams::new(3, 8),
                    DecompParams::new(2, 10),
                    DecompParams::new(2, 14),
                ] {
                    // n search: binary search on the feasibility edge.
                    if let Some(n) = min_feasible_lwe_dim(
                        msg_bits,
                        poly_size,
                        glwe_noise,
                        DecompParams::new(base_log, level),
                        ks,
                        profile.linear_growth,
                        cfg,
                    ) {
                        let p = TfheParams::search_candidate(
                            n,
                            poly_size,
                            glwe_noise,
                            DecompParams::new(base_log, level),
                            ks,
                            msg_bits,
                            cfg.security,
                        );
                        let cost = circuit_cost(&p, profile.pbs_count, profile.linear_ops).0;
                        let improved = match &best {
                            Some((c, _)) => cost < *c,
                            None => true,
                        };
                        if improved {
                            best = Some((cost, p));
                        }
                    }
                }
            }
        }
    }
    best.map(|(circuit_flops, params)| OptimizedParams {
        params,
        circuit_flops,
        profile: *profile,
    })
}

/// Smallest LWE dimension that makes the set feasible (binary search over
/// a monotone predicate: larger n ⇒ less noise ⇒ feasible).
fn min_feasible_lwe_dim(
    msg_bits: u32,
    poly_size: usize,
    glwe_noise: f64,
    pbs_decomp: DecompParams,
    ks_decomp: DecompParams,
    linear_growth: f64,
    cfg: SearchConfig,
) -> Option<usize> {
    let feasible = |n: usize| -> bool {
        let p = TfheParams::search_candidate(
            n,
            poly_size,
            glwe_noise,
            pbs_decomp,
            ks_decomp,
            msg_bits,
            cfg.security,
        );
        params_feasible(&p, linear_growth, cfg.p_fail)
    };
    let (mut lo, mut hi) = (500usize, 1100usize);
    if !feasible(hi) {
        return None;
    }
    if feasible(lo) {
        return Some(lo);
    }
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// One row of the paper's Table 2.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub mechanism: &'static str,
    pub seq_len: usize,
    pub lwe_dim: usize,
    pub base_log: usize,
    pub level: usize,
    pub poly_size: usize,
    pub int_bits: u32,
    pub uint_bits: u32,
    pub pbs_count: u64,
    pub est_pbs_ms: f64,
}

/// Regenerate Table 2 for the given sequence lengths (d=2, 3-bit inputs,
/// as in the paper's scaling experiments).
pub fn table2(seq_lens: &[usize], flops_per_sec: f64) -> Vec<Table2Row> {
    use crate::attention::Mechanism;
    let mut rows = Vec::new();
    for &t in seq_lens {
        for mech in [Mechanism::Inhibitor, Mechanism::DotProduct] {
            let prof = super::precision::profile(mech, t, 2, 3);
            if let Some(opt) = optimize(&prof, SearchConfig::default()) {
                rows.push(Table2Row {
                    mechanism: mech.name(),
                    seq_len: t,
                    lwe_dim: opt.params.lwe_dim,
                    base_log: opt.params.pbs_decomp.base_log,
                    level: opt.params.pbs_decomp.level,
                    poly_size: opt.params.poly_size,
                    int_bits: prof.int_bits,
                    uint_bits: prof.uint_bits,
                    pbs_count: prof.pbs_count,
                    est_pbs_ms: pbs_cost(&opt.params).seconds(flops_per_sec) * 1e3,
                });
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Mechanism;
    use crate::optimizer::precision::profile;

    #[test]
    fn optimizer_finds_feasible_params_for_all_table2_cells() {
        for t in [2usize, 4, 8, 16] {
            for mech in [Mechanism::Inhibitor, Mechanism::DotProduct] {
                let prof = profile(mech, t, 2, 3);
                let opt = optimize(&prof, SearchConfig::default())
                    .unwrap_or_else(|| panic!("no params for {mech:?} T={t}"));
                opt.params.validate().unwrap();
                assert!(
                    params_feasible(&opt.params, prof.linear_growth, SearchConfig::default().p_fail),
                    "{mech:?} T={t}"
                );
            }
        }
    }

    #[test]
    fn optimized_params_mirror_table2_shape() {
        // Paper Table 2 shape: lweDim ∈ ~[750, 950], polySize ∈ {2048, 4096},
        // dot-product needs ≥ inhibitor in both polySize and message bits.
        for t in [4usize, 16] {
            let inh = optimize(&profile(Mechanism::Inhibitor, t, 2, 3), SearchConfig::default())
                .unwrap();
            let dot = optimize(&profile(Mechanism::DotProduct, t, 2, 3), SearchConfig::default())
                .unwrap();
            assert!((600..=1000).contains(&inh.params.lwe_dim), "inh n={}", inh.params.lwe_dim);
            assert!(dot.params.poly_size >= inh.params.poly_size, "T={t}");
            assert!(
                dot.params.message_bits > inh.params.message_bits,
                "T={t}: {} vs {}",
                dot.params.message_bits,
                inh.params.message_bits
            );
            // And the circuit itself is costlier end to end.
            assert!(dot.circuit_flops > 1.5 * inh.circuit_flops, "T={t}");
        }
    }

    #[test]
    fn binary_search_monotonicity() {
        // If n0 is returned, n0 is feasible and n0−1 is not (or n0 == 500).
        let cfg = SearchConfig::default();
        let n = min_feasible_lwe_dim(4, 2048, min_noise_for_security(2048, 128),
            DecompParams::new(23, 1), DecompParams::new(4, 6), 8.0, cfg);
        if let Some(n) = n {
            assert!((500..=1100).contains(&n));
        }
    }

    #[test]
    fn table2_produces_all_rows() {
        let rows = table2(&[2, 4], 1e9);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.est_pbs_ms > 0.0);
        }
    }
}
