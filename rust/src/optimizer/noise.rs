//! TFHE noise model (S7), after Bergerat et al. 2023.
//!
//! Tracks noise *variance* (as squared torus fraction) through each FHE
//! operator and converts the end-of-circuit variance into a decode
//! failure probability. The security curve maps an LWE dimension to the
//! minimum tolerable fresh-noise σ at a given security level — a linear
//! log₂σ(n) fit to lattice-estimator output for ternary/binary secrets
//! (the same family of fits Concrete's optimizer uses internally).

use crate::tfhe::params::TfheParams;

/// Minimum fresh-noise standard deviation (torus fraction) for an
/// LWE/GLWE instance of total dimension `dim` at security level `lambda`.
///
/// Fit anchors (λ=128, q=2^64, binary secrets): (n=742, σ=2^-17.1),
/// (n=2048, σ=2^-52) → log₂σ ≈ 2.71 − 0.0267·n. Floored at 2^-55: noise
/// below the f64-FFT error floor buys nothing.
pub fn min_noise_for_security(dim: usize, lambda: u32) -> f64 {
    let scale = lambda as f64 / 128.0;
    let log2_sigma = 2.71 - 0.0267 * dim as f64 / scale;
    2f64.powf(log2_sigma.clamp(-55.0, -2.0))
}

/// Variance bookkeeping for a ciphertext.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Var(pub f64);

impl Var {
    pub fn fresh_lwe(p: &TfheParams) -> Var {
        Var(p.lwe_noise_std * p.lwe_noise_std)
    }

    pub fn add(self, o: Var) -> Var {
        Var(self.0 + o.0)
    }

    /// Multiplication by a plaintext literal `c`.
    pub fn scalar_mul(self, c: i64) -> Var {
        Var(self.0 * (c as f64) * (c as f64))
    }

    /// Sum of `k` independent ciphertexts at this variance.
    pub fn sum_of(self, k: usize) -> Var {
        Var(self.0 * k as f64)
    }

    pub fn std(self) -> f64 {
        self.0.sqrt()
    }
}

/// Variance added by the mod-switch to Z_{2N} before blind rotation,
/// expressed on the torus *input* scale (it perturbs the phase the blind
/// rotation resolves).
pub fn mod_switch_var(p: &TfheParams) -> f64 {
    let two_n = (2 * p.poly_size) as f64;
    // Rounding each of n mask coefficients (uniform in ±1/(2·2N)) plus the
    // body: variance (n/2 + 1) · 1/(12·(2N)²)   [s_i ∈ {0,1}, E[s]=1/2].
    ((p.lwe_dim as f64) / 2.0 + 1.0) / (12.0 * two_n * two_n)
}

/// Output variance of a PBS (independent of input noise — PBS resets it).
///
/// Two contributions (standard TFHE estimates):
/// * blind rotation: n CMux, each an external product against a GGSW at
///   σ_glwe with decomposition (B = 2^baseLog, ℓ levels):
///   `n · ℓ · (k+1) · N · (B²+2)/12 · σ_glwe²`
/// * decomposition (gadget) error: `n · (1 + k·N) / 2 · B^(−2ℓ) / 12`.
pub fn pbs_output_var(p: &TfheParams) -> f64 {
    let n = p.lwe_dim as f64;
    let nn = p.poly_size as f64;
    let k = p.glwe_dim as f64;
    let l = p.pbs_decomp.level as f64;
    let b = 2f64.powi(p.pbs_decomp.base_log as i32);
    let v_br = n * l * (k + 1.0) * nn * (b * b + 2.0) / 12.0 * p.glwe_noise_std * p.glwe_noise_std;
    let v_dec = n * (1.0 + k * nn) / 2.0 * b.powf(-2.0 * l) / 12.0;
    v_br + v_dec
}

/// Variance added by the key switch back to the small key.
pub fn keyswitch_var(p: &TfheParams) -> f64 {
    let kn = p.extracted_lwe_dim() as f64;
    let l = p.ks_decomp.level as f64;
    let b = 2f64.powi(p.ks_decomp.base_log as i32);
    // Each decomposed digit multiplies a KSK row at σ_lwe, plus the
    // decomposition rounding of each of k·N coefficients.
    let v_rows = kn * l * (b * b / 12.0) * p.lwe_noise_std * p.lwe_noise_std;
    let v_dec = kn / 2.0 * b.powf(-2.0 * l) / 12.0;
    v_rows + v_dec
}

/// Total variance of a post-PBS ciphertext (PBS + KS).
pub fn post_pbs_var(p: &TfheParams) -> f64 {
    pbs_output_var(p) + keyswitch_var(p)
}

/// Complementary error function (Abramowitz & Stegun 7.1.26, |ε|≤1.5e-7).
pub fn erfc(x: f64) -> f64 {
    let sign_neg = x < 0.0;
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))))
        * (-x * x).exp();
    if sign_neg {
        2.0 - y
    } else {
        y
    }
}

/// Probability that Gaussian noise of variance `var` pushes a phase out of
/// its half-slot window of radius `delta_half` (as torus fractions).
pub fn decode_failure_prob(var: f64, delta_half: f64) -> f64 {
    if var <= 0.0 {
        return 0.0;
    }
    erfc(delta_half / (2.0f64.sqrt() * var.sqrt()))
}

/// End-to-end check: can `p` evaluate circuits where ciphertexts carry at
/// most `max_linear_ops` accumulated linear operations between PBS, with
/// per-PBS failure ≤ `p_fail`?
///
/// Two constraints (both must hold):
/// 1. decode/PBS-input: post-PBS noise × linear growth + mod-switch noise
///    must resolve the message slot,
/// 2. fresh encryption must also satisfy (1) (client inputs).
pub fn params_feasible(p: &TfheParams, linear_growth: f64, p_fail: f64) -> bool {
    let delta_half = 2f64.powi(-(p.message_bits as i32) - 2); // Δ/2 as fraction
    let worst_in = post_pbs_var(p).max(p.lwe_noise_std * p.lwe_noise_std) * linear_growth;
    let at_rotation = worst_in + mod_switch_var(p);
    decode_failure_prob(at_rotation, delta_half) <= p_fail
}

/// Packed-path variant of [`params_feasible`]: a `2^ϑ`-way multi-value
/// bootstrap pre-rotates by the *coarse* half-slot, so the phase must
/// clear a window ϑ bits narrower than the standard mod-switch target —
/// the "coarse-rounding headroom" a set spends when it advertises
/// `many_lut_log > 0`. Degenerates to the standard check at ϑ = 0.
pub fn params_feasible_packed(p: &TfheParams, linear_growth: f64, p_fail: f64) -> bool {
    let delta_half = 2f64.powi(-(p.message_bits as i32) - 2 - p.many_lut_log as i32);
    let worst_in = post_pbs_var(p).max(p.lwe_noise_std * p.lwe_noise_std) * linear_growth;
    let at_rotation = worst_in + mod_switch_var(p);
    decode_failure_prob(at_rotation, delta_half) <= p_fail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn security_curve_monotone() {
        let s1 = min_noise_for_security(600, 128);
        let s2 = min_noise_for_security(800, 128);
        let s3 = min_noise_for_security(1024, 128);
        assert!(s1 > s2 && s2 > s3, "more dimension allows less noise");
        // Anchor sanity: n=742 ⇒ σ ≈ 2^-17ish.
        let a = min_noise_for_security(742, 128).log2();
        assert!((-18.0..=-16.0).contains(&a), "{a}");
    }

    #[test]
    fn erfc_reference_values() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-6);
        assert!((erfc(1.0) - 0.157299).abs() < 1e-5);
        assert!((erfc(2.0) - 0.004678).abs() < 1e-5);
        assert!((erfc(-1.0) - 1.842701).abs() < 1e-5);
    }

    #[test]
    fn variance_tracking_ops() {
        let v = Var(1e-12);
        assert!((v.add(v).0 - 2e-12).abs() < 1e-20);
        assert!((v.scalar_mul(3).0 - 9e-12).abs() < 1e-20);
        assert!((v.sum_of(4).0 - 4e-12).abs() < 1e-20);
    }

    #[test]
    fn test_small_params_are_feasible() {
        let p = TfheParams::test_small();
        assert!(params_feasible(&p, 4.0, 1e-3), "test_small should decode reliably");
    }

    #[test]
    fn bench_sets_are_feasible() {
        for bits in 2..=7 {
            let p = TfheParams::bench_for_bits(bits);
            assert!(
                params_feasible(&p, 8.0, 2f64.powi(-17)),
                "bench set {bits} bits infeasible: pbs_var={:e} ms_var={:e}",
                post_pbs_var(&p),
                mod_switch_var(&p)
            );
        }
    }

    #[test]
    fn bench_packed_sets_are_feasible() {
        // The noise side of the coarse-rounding headroom invariant:
        // every bench width that advertises a packed budget must clear
        // the ϑ-bits-narrower half-slot at the same linear growth and
        // failure class the unpacked bench check uses — packing may
        // spend headroom, never correctness.
        let mut packed = 0;
        for bits in 2..=7 {
            let p = TfheParams::bench_for_bits(bits);
            packed += (p.many_lut_log > 0) as u32;
            assert!(
                params_feasible_packed(&p, 8.0, 2f64.powi(-17)),
                "bench set {bits} bits infeasible at ϑ={}: pbs_var={:e} ms_var={:e}",
                p.many_lut_log,
                post_pbs_var(&p),
                mod_switch_var(&p)
            );
        }
        assert!(packed >= 3, "bench curve must provision packing on the low widths");
        // At ϑ = 0 the packed check is exactly the standard one.
        let p = TfheParams::bench_for_bits(7);
        assert_eq!(p.many_lut_log, 0);
        assert_eq!(
            params_feasible_packed(&p, 8.0, 2f64.powi(-17)),
            params_feasible(&p, 8.0, 2f64.powi(-17))
        );
    }

    #[test]
    fn failure_prob_decreases_with_margin() {
        let p1 = decode_failure_prob(1e-4, 0.01);
        let p2 = decode_failure_prob(1e-4, 0.02);
        assert!(p2 < p1, "{p2} !< {p1}");
        assert!(p1 < 1.0 && p2 > 0.0);
    }
}
