//! In-tree tensor types (S1): integer compute substrate + float reference.

pub mod ftensor;
pub mod itensor;
pub mod shape;

pub use ftensor::FTensor;
pub use itensor::{signed_bits_for, unsigned_bits_for, ITensor};
pub use shape::Shape;
