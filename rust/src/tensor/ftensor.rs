//! Float tensor: host-side reference arithmetic and (de)quantization
//! endpoints. The request path proper runs integers (`ITensor`) or
//! ciphertexts; `FTensor` exists for calibration, accuracy checks and the
//! PJRT float path boundary.

use super::shape::Shape;
use crate::util::prng::Xoshiro256;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct FTensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl FTensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        FTensor { shape, data: vec![0.0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), data.len(), "data length does not match shape {shape}");
        FTensor { shape, data }
    }

    /// Standard-normal random tensor (tests/benches).
    pub fn randn(dims: &[usize], std: f32, rng: &mut Xoshiro256) -> Self {
        let shape = Shape::new(dims);
        let data = (0..shape.numel()).map(|_| rng.next_gaussian_std(std as f64) as f32).collect();
        FTensor { shape, data }
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape.0[1] + j]
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        FTensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip(&self, other: &Self, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self.data.iter().zip(other.data.iter()).map(|(&a, &b)| f(a, b)).collect();
        FTensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, o: &Self) -> Self {
        self.zip(o, |a, b| a + b)
    }

    pub fn sub(&self, o: &Self) -> Self {
        self.zip(o, |a, b| a - b)
    }

    pub fn relu(&self) -> Self {
        self.map(|x| x.max(0.0))
    }

    pub fn matmul(&self, other: &Self) -> Self {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.dims()[0], self.dims()[1]);
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        FTensor::from_vec(&[m, n], out)
    }

    pub fn transpose2(&self) -> Self {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        FTensor::from_vec(&[n, m], out)
    }

    /// Row-wise softmax of a rank-2 tensor (reference for the quantized
    /// dot-product baseline).
    pub fn softmax_rows(&self) -> Self {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&x| (x - mx).exp()).collect();
            let s: f32 = exps.iter().sum();
            for j in 0..n {
                out[i * n + j] = exps[j] / s;
            }
        }
        FTensor::from_vec(&[m, n], out)
    }

    /// Max |a - b| between two tensors of equal shape.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn min(&self) -> f32 {
        self.data.iter().cloned().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_normalized() {
        let t = FTensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let s = t.softmax_rows();
        for i in 0..2 {
            let row: f32 = (0..3).map(|j| s.at2(i, j)).sum();
            assert!((row - 1.0).abs() < 1e-6);
        }
        // Monotone: bigger logit, bigger prob.
        assert!(s.at2(0, 2) > s.at2(0, 1));
    }

    #[test]
    fn softmax_shift_invariance() {
        let t = FTensor::from_vec(&[1, 3], vec![10.0, 11.0, 12.0]);
        let u = t.map(|x| x + 100.0);
        assert!(t.softmax_rows().max_abs_diff(&u.softmax_rows()) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = FTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = FTensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&b), a);
    }

    #[test]
    fn randn_spread() {
        let mut rng = Xoshiro256::new(3);
        let t = FTensor::randn(&[100, 100], 1.0, &mut rng);
        let mean: f32 = t.data.iter().sum::<f32>() / t.numel() as f32;
        assert!(mean.abs() < 0.05);
    }
}
