//! Shapes and row-major indexing for the in-tree tensor types.

use std::fmt;

/// A tensor shape (row-major). Most of the attention stack is rank-2/3,
/// but the type is rank-generic so model code stays readable.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.0.len()];
        for i in (0..self.0.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Flat offset of a multi-index (bounds-checked in debug builds).
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.0.len(), "index rank mismatch");
        let strides = self.strides();
        let mut off = 0;
        for (k, (&i, &d)) in idx.iter().zip(self.0.iter()).enumerate() {
            debug_assert!(i < d, "index {i} out of bounds for dim {k} (size {d})");
            off += i * strides[k];
        }
        off
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.offset(&[1, 2, 3]), 12 + 8 + 3);
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(&[]);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.offset(&[]), 0);
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(&[4, 8]).to_string(), "[4, 8]");
    }
}
