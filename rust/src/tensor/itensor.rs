//! Integer tensor: the plaintext quantized compute substrate (S1).
//!
//! The paper's Table 3 experiment implements both attention mechanisms
//! "directly in low-level code ... integer 16-bit arithmetics implemented
//! in the Rust programming language". `ITensor` mirrors that: values are
//! conceptually int16 (or narrower) quantized codes, stored as `i64` so
//! intermediate accumulations (matmul over d, sums over sequence length)
//! cannot overflow before the requantization step. Debug assertions verify
//! declared bit-widths; release builds pay no checking cost on the hot
//! path.

use super::shape::Shape;
use crate::util::prng::{Rng64, Xoshiro256};

/// Dense row-major integer tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ITensor {
    pub shape: Shape,
    pub data: Vec<i64>,
}

impl ITensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        ITensor { shape, data: vec![0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<i64>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), data.len(), "data length does not match shape {shape}");
        ITensor { shape, data }
    }

    /// Uniform random tensor in `[lo, hi]`, for tests and benches.
    pub fn random(dims: &[usize], lo: i64, hi: i64, rng: &mut Xoshiro256) -> Self {
        let shape = Shape::new(dims);
        let data = (0..shape.numel()).map(|_| rng.next_range_i64(lo, hi)).collect();
        ITensor { shape, data }
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn get(&self, idx: &[usize]) -> i64 {
        self.data[self.shape.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: i64) {
        let off = self.shape.offset(idx);
        self.data[off] = v;
    }

    /// 2-D accessor (hot path; avoids building an index slice).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> i64 {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.shape.0[1];
        self.data[i * cols + j]
    }

    /// Reshape without copying (numel must match).
    pub fn reshape(mut self, dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), self.data.len(), "reshape numel mismatch");
        self.shape = shape;
        self
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(i64) -> i64) -> Self {
        ITensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Self, f: impl Fn(i64, i64) -> i64) -> Self {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self.data.iter().zip(other.data.iter()).map(|(&a, &b)| f(a, b)).collect();
        ITensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a - b)
    }

    /// Multiply by a plaintext literal (cheap everywhere, incl. under FHE).
    pub fn scalar_mul(&self, c: i64) -> Self {
        self.map(|x| x * c)
    }

    /// ReLU: x⁺ = max(0, x) (paper shorthand).
    pub fn relu(&self) -> Self {
        self.map(|x| x.max(0))
    }

    /// Negative ReLU: x⁻ = min(0, x) (paper eq. 11 context).
    pub fn neg_relu(&self) -> Self {
        self.map(|x| x.min(0))
    }

    pub fn abs(&self) -> Self {
        self.map(|x| x.abs())
    }

    /// Matrix multiply, `self: [m,k] × other: [k,n] -> [m,n]`.
    /// i64 accumulation; this is the "expensive" op the Inhibitor avoids.
    pub fn matmul(&self, other: &Self) -> Self {
        assert_eq!(self.rank(), 2, "matmul lhs must be rank 2");
        assert_eq!(other.rank(), 2, "matmul rhs must be rank 2");
        let (m, k) = (self.dims()[0], self.dims()[1]);
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch: {k} vs {k2}");
        let mut out = vec![0i64; m * n];
        // ikj loop order: streams `other` rows, good cache behaviour.
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        ITensor::from_vec(&[m, n], out)
    }

    /// Column slice of a rank-2 tensor: `[m, n] → [m, width]` starting
    /// at `col0` (the multi-head split: head h reads columns
    /// `[h·d, (h+1)·d)`).
    pub fn slice_cols(&self, col0: usize, width: usize) -> Self {
        assert_eq!(self.rank(), 2, "slice_cols needs a rank-2 tensor");
        let (m, n) = (self.dims()[0], self.dims()[1]);
        assert!(col0 + width <= n, "column slice [{col0}, {}) exceeds width {n}", col0 + width);
        let mut data = Vec::with_capacity(m * width);
        for i in 0..m {
            data.extend_from_slice(&self.data[i * n + col0..i * n + col0 + width]);
        }
        ITensor::from_vec(&[m, width], data)
    }

    /// Concatenate rank-2 tensors along columns (equal row counts) —
    /// the multi-head "concat" joining per-head outputs back into
    /// `[m, Σ widths]`.
    pub fn concat_cols(parts: &[&ITensor]) -> Self {
        assert!(!parts.is_empty(), "concat of zero tensors");
        let m = parts[0].dims()[0];
        let mut total = 0usize;
        for p in parts {
            assert_eq!(p.rank(), 2, "concat_cols needs rank-2 tensors");
            assert_eq!(p.dims()[0], m, "concat_cols row count mismatch");
            total += p.dims()[1];
        }
        let mut data = Vec::with_capacity(m * total);
        for i in 0..m {
            for p in parts {
                let w = p.dims()[1];
                data.extend_from_slice(&p.data[i * w..(i + 1) * w]);
            }
        }
        ITensor::from_vec(&[m, total], data)
    }

    /// Transpose a rank-2 tensor.
    pub fn transpose2(&self) -> Self {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0i64; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        ITensor::from_vec(&[n, m], out)
    }

    /// Pairwise Manhattan distance between rows:
    /// `self: [m,d], other: [n,d] -> [m,n]`, `out[i][j] = Σ_k |a_ik − b_jk|`.
    /// This is the paper's eq. 5 numerator (the fused `cdist` the appendix
    /// recommends) — additions and absolute values only, no products.
    pub fn manhattan_cdist(&self, other: &Self) -> Self {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, d) = (self.dims()[0], self.dims()[1]);
        let (n, d2) = (other.dims()[0], other.dims()[1]);
        assert_eq!(d, d2, "cdist feature dim mismatch");
        let mut out = vec![0i64; m * n];
        for i in 0..m {
            let a = &self.data[i * d..(i + 1) * d];
            let orow = &mut out[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let b = &other.data[j * d..(j + 1) * d];
                let mut s = 0i64;
                for k in 0..d {
                    s += (a[k] - b[k]).abs();
                }
                *o = s;
            }
        }
        ITensor::from_vec(&[m, n], out)
    }

    /// Sum along an axis of a rank-2 tensor: axis=0 -> [n], axis=1 -> [m].
    pub fn sum_axis2(&self, axis: usize) -> Vec<i64> {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims()[0], self.dims()[1]);
        match axis {
            0 => {
                let mut out = vec![0i64; n];
                for i in 0..m {
                    for j in 0..n {
                        out[j] += self.data[i * n + j];
                    }
                }
                out
            }
            1 => {
                let mut out = vec![0i64; m];
                for i in 0..m {
                    out[i] = self.data[i * n..(i + 1) * n].iter().sum();
                }
                out
            }
            _ => panic!("axis must be 0 or 1 for rank-2 sum"),
        }
    }

    /// Largest absolute value (0 for empty tensors).
    pub fn max_abs(&self) -> i64 {
        self.data.iter().map(|x| x.abs()).max().unwrap_or(0)
    }

    /// Minimum / maximum values.
    pub fn min(&self) -> i64 {
        self.data.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> i64 {
        self.data.iter().copied().max().unwrap_or(0)
    }

    /// Number of signed bits needed to represent every entry (incl. sign).
    /// Matches the "int" column of the paper's Table 2.
    pub fn signed_bits(&self) -> u32 {
        signed_bits_for(self.min(), self.max())
    }

    /// Assert every entry fits in `bits`-bit signed integers (debug aid;
    /// the quantized engine calls this after each requantization).
    pub fn check_bits(&self, bits: u32) -> Result<(), String> {
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        for (i, &v) in self.data.iter().enumerate() {
            if v < lo || v > hi {
                return Err(format!("value {v} at flat index {i} exceeds int{bits} [{lo},{hi}]"));
            }
        }
        Ok(())
    }
}

/// Signed bits needed to cover `[min, max]`.
pub fn signed_bits_for(min: i64, max: i64) -> u32 {
    let mut bits = 1;
    loop {
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        if min >= lo && max <= hi {
            return bits;
        }
        bits += 1;
    }
}

/// Unsigned bits needed to cover `[0, max]` (Table 2 "uint" column).
pub fn unsigned_bits_for(max: i64) -> u32 {
    assert!(max >= 0);
    let mut bits = 1;
    while (1i64 << bits) - 1 < max {
        bits += 1;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_assert, prop_assert_eq, prop_check};

    #[test]
    fn matmul_known() {
        let a = ITensor::from_vec(&[2, 2], vec![1, 2, 3, 4]);
        let b = ITensor::from_vec(&[2, 2], vec![5, 6, 7, 8]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19, 22, 43, 50]);
    }

    #[test]
    fn matmul_identity_property() {
        prop_check("A·I == A", 64, |rng| {
            let m = 1 + rng.next_bounded(6) as usize;
            let n = 1 + rng.next_bounded(6) as usize;
            let a = ITensor::random(&[m, n], -50, 50, rng);
            let mut eye = ITensor::zeros(&[n, n]);
            for i in 0..n {
                eye.set(&[i, i], 1);
            }
            prop_assert_eq(a.matmul(&eye), a, "identity")
        });
    }

    #[test]
    fn matmul_matches_naive_property() {
        prop_check("fast matmul == naive", 32, |rng| {
            let (m, k, n) = (
                1 + rng.next_bounded(5) as usize,
                1 + rng.next_bounded(5) as usize,
                1 + rng.next_bounded(5) as usize,
            );
            let a = ITensor::random(&[m, k], -30, 30, rng);
            let b = ITensor::random(&[k, n], -30, 30, rng);
            let fast = a.matmul(&b);
            let mut naive = ITensor::zeros(&[m, n]);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0;
                    for kk in 0..k {
                        s += a.at2(i, kk) * b.at2(kk, j);
                    }
                    naive.set(&[i, j], s);
                }
            }
            prop_assert_eq(fast, naive, "matmul")
        });
    }

    #[test]
    fn cdist_known() {
        // rows a=(0,0),(3,4); b=(1,1)
        let a = ITensor::from_vec(&[2, 2], vec![0, 0, 3, 4]);
        let b = ITensor::from_vec(&[1, 2], vec![1, 1]);
        let d = a.manhattan_cdist(&b);
        assert_eq!(d.dims(), &[2, 1]);
        assert_eq!(d.data, vec![2, 5]);
    }

    #[test]
    fn cdist_symmetry_and_triangle() {
        prop_check("cdist metric axioms", 48, |rng| {
            let n = 2 + rng.next_bounded(4) as usize;
            let d = 1 + rng.next_bounded(4) as usize;
            let x = ITensor::random(&[n, d], -20, 20, rng);
            let dist = x.manhattan_cdist(&x);
            for i in 0..n {
                prop_assert_eq(dist.at2(i, i), 0, "self distance zero")?;
                for j in 0..n {
                    prop_assert_eq(dist.at2(i, j), dist.at2(j, i), "symmetry")?;
                    for l in 0..n {
                        prop_assert(
                            dist.at2(i, j) <= dist.at2(i, l) + dist.at2(l, j),
                            "triangle inequality",
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn relu_variants() {
        let t = ITensor::from_vec(&[5], vec![-2, -1, 0, 1, 2]);
        assert_eq!(t.relu().data, vec![0, 0, 0, 1, 2]);
        assert_eq!(t.neg_relu().data, vec![-2, -1, 0, 0, 0]);
        assert_eq!(t.abs().data, vec![2, 1, 0, 1, 2]);
        // eq. 8: x⁺ = (x + |x|)/2 and eq. 11: x⁻ = (x − |x|)/2
        let plus = t.add(&t.abs()).map(|v| v / 2);
        let minus = t.sub(&t.abs()).map(|v| v / 2);
        assert_eq!(plus, t.relu());
        assert_eq!(minus, t.neg_relu());
    }

    #[test]
    fn slice_and_concat_cols_roundtrip() {
        prop_check("concat(slices) == original", 32, |rng| {
            let m = 1 + rng.next_bounded(5) as usize;
            let h = 1 + rng.next_bounded(3) as usize;
            let d = 1 + rng.next_bounded(4) as usize;
            let a = ITensor::random(&[m, h * d], -50, 50, rng);
            let parts: Vec<ITensor> = (0..h).map(|i| a.slice_cols(i * d, d)).collect();
            let refs: Vec<&ITensor> = parts.iter().collect();
            prop_assert_eq(ITensor::concat_cols(&refs), a, "roundtrip")
        });
        let t = ITensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.slice_cols(1, 2).data, vec![2, 3, 5, 6]);
    }

    #[test]
    fn transpose_involution() {
        prop_check("(Aᵀ)ᵀ == A", 32, |rng| {
            let m = 1 + rng.next_bounded(6) as usize;
            let n = 1 + rng.next_bounded(6) as usize;
            let a = ITensor::random(&[m, n], -100, 100, rng);
            prop_assert_eq(a.transpose2().transpose2(), a, "involution")
        });
    }

    #[test]
    fn sums_and_bits() {
        let t = ITensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.sum_axis2(0), vec![5, 7, 9]);
        assert_eq!(t.sum_axis2(1), vec![6, 15]);
        assert_eq!(signed_bits_for(-8, 7), 4);
        assert_eq!(signed_bits_for(-9, 0), 5);
        assert_eq!(unsigned_bits_for(15), 4);
        assert_eq!(unsigned_bits_for(16), 5);
        assert!(t.check_bits(4).is_ok());
        assert!(t.check_bits(3).is_err());
    }

    #[test]
    #[should_panic(expected = "inner dim mismatch")]
    fn matmul_shape_check() {
        let a = ITensor::zeros(&[2, 3]);
        let b = ITensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }
}
