//! PJRT runtime (S8): loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.

pub mod engine;
pub mod registry;

pub use engine::{Engine, Runtime};
pub use registry::Registry;
