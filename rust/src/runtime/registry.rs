//! Artifact registry (S8): reads `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and lazily loads/compiles engines on demand,
//! keyed by (mechanism, seq_len) for attention heads or by model name.

use super::engine::{Engine, Runtime};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// One catalog entry for an attention-head artifact.
#[derive(Clone, Debug)]
pub struct AttnEntry {
    pub name: String,
    pub mechanism: String,
    pub seq_len: usize,
    pub dim: usize,
    pub file: String,
}

/// One catalog entry for a full-model artifact.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub file: String,
    pub weights: String,
    pub config: Json,
}

/// The registry: manifest + lazily compiled engines.
pub struct Registry {
    pub dir: PathBuf,
    pub attention: Vec<AttnEntry>,
    pub models: Vec<ModelEntry>,
    runtime: Runtime,
    engines: HashMap<String, Engine>,
}

impl Registry {
    /// Open an artifact directory (must contain manifest.json).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let attention = j
            .get("attention")
            .and_then(|a| a.as_arr())
            .unwrap_or(&[])
            .iter()
            .filter_map(|e| {
                Some(AttnEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    mechanism: e.get("mechanism")?.as_str()?.to_string(),
                    seq_len: e.get("seq_len")?.as_i64()? as usize,
                    dim: e.get("dim")?.as_i64()? as usize,
                    file: e.get("file")?.as_str()?.to_string(),
                })
            })
            .collect();
        let models = j
            .get("models")
            .and_then(|a| a.as_arr())
            .unwrap_or(&[])
            .iter()
            .filter_map(|e| {
                Some(ModelEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    weights: e.get("weights")?.as_str()?.to_string(),
                    config: e.get("config")?.clone(),
                })
            })
            .collect();
        Ok(Registry { dir, attention, models, runtime: Runtime::cpu()?, engines: HashMap::new() })
    }

    /// Get (compiling on first use) the attention engine for a variant.
    pub fn attention_engine(&mut self, mechanism: &str, seq_len: usize) -> Result<&Engine> {
        let entry = self
            .attention
            .iter()
            .find(|e| e.mechanism == mechanism && e.seq_len == seq_len)
            .cloned()
            .ok_or_else(|| anyhow!("no artifact for mechanism={mechanism} T={seq_len}"))?;
        if !self.engines.contains_key(&entry.name) {
            let shapes = vec![(entry.seq_len, entry.dim); 3];
            let engine =
                self.runtime.load_hlo_text(&self.dir.join(&entry.file), shapes, &entry.name)?;
            self.engines.insert(entry.name.clone(), engine);
        }
        Ok(&self.engines[&entry.name])
    }

    /// Get (compiling on first use) a full-model engine by name.
    pub fn model_engine(&mut self, name: &str) -> Result<&Engine> {
        let entry = self
            .models
            .iter()
            .find(|e| e.name == name)
            .cloned()
            .ok_or_else(|| anyhow!("no model artifact named '{name}'"))?;
        if !self.engines.contains_key(&entry.name) {
            let seq = entry.config.get("seq_len").and_then(|v| v.as_i64()).unwrap_or(0) as usize;
            let feat =
                entry.config.get("in_features").and_then(|v| v.as_i64()).unwrap_or(0) as usize;
            let engine = self.runtime.load_hlo_text(
                &self.dir.join(&entry.file),
                vec![(seq, feat)],
                &entry.name,
            )?;
            self.engines.insert(entry.name.clone(), engine);
        }
        Ok(&self.engines[&entry.name])
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }
}
