//! PJRT execution engine (S8): load AOT-compiled HLO text artifacts and
//! execute them from the Rust request path. Wraps the `xla` crate
//! (xla_extension 0.5.1, CPU plugin). Python never runs here.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A compiled executable plus its I/O contract.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    /// (rows, cols) of each input the entry computation expects.
    pub input_shapes: Vec<(usize, usize)>,
    pub name: String,
}

/// Shared PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text artifact and compile it for this client.
    pub fn load_hlo_text(
        &self,
        path: &Path,
        input_shapes: Vec<(usize, usize)>,
        name: &str,
    ) -> Result<Engine> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Engine { exe, input_shapes, name: name.to_string() })
    }
}

impl Engine {
    /// Execute with f32 matrices (row-major `Vec<f32>` + shape pairs).
    /// Returns the first tuple element flattened (artifacts are lowered
    /// with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            inputs.len() == self.input_shapes.len(),
            "engine '{}' expects {} inputs, got {}",
            self.name,
            self.input_shapes.len(),
            inputs.len()
        );
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, &(r, c)) in inputs.iter().zip(self.input_shapes.iter()) {
            anyhow::ensure!(
                data.len() == r * c,
                "engine '{}': input data {} does not match shape {}x{}",
                self.name,
                data.len(),
                r,
                c
            );
            let lit = xla::Literal::vec1(data);
            lits.push(if c == 0 {
                lit // rank-1 input
            } else {
                lit.reshape(&[r as i64, c as i64])?
            });
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
