//! Plaintext quantized attention engines (S2): the Inhibitor (paper
//! eqs. 5–10) and the conventional dot-product + Softmax baseline.

pub mod common;
pub mod dotprod;
pub mod inhibitor;

pub use common::{AttnConfig, HeadSplit, Mechanism};
pub use dotprod::{DotProductHead, IntSoftmax};
pub use inhibitor::InhibitorHead;

use crate::tensor::ITensor;

/// Unified head interface so the model and benches can swap mechanisms.
pub enum AttentionHead {
    DotProduct(DotProductHead),
    Inhibitor(InhibitorHead),
}

impl AttentionHead {
    /// Construct the head named by `cfg.mechanism` with sensible defaults:
    /// 10-bit score codes for the dot-product LUT, fused inhibitor forms.
    pub fn build(cfg: AttnConfig, code_scale: f32) -> Self {
        match cfg.mechanism {
            Mechanism::DotProduct => {
                AttentionHead::DotProduct(DotProductHead::from_config(cfg, code_scale, 10))
            }
            Mechanism::Inhibitor => {
                AttentionHead::Inhibitor(InhibitorHead::from_config(cfg, code_scale, false))
            }
            Mechanism::InhibitorSigned => {
                AttentionHead::Inhibitor(InhibitorHead::from_config(cfg, code_scale, true))
            }
        }
    }

    pub fn forward(&self, q: &ITensor, k: &ITensor, v: &ITensor) -> ITensor {
        match self {
            AttentionHead::DotProduct(h) => h.forward(q, k, v),
            AttentionHead::Inhibitor(h) => h.forward(q, k, v),
        }
    }

    pub fn mechanism(&self) -> Mechanism {
        match self {
            AttentionHead::DotProduct(h) => h.cfg.mechanism,
            AttentionHead::Inhibitor(h) => h.cfg.mechanism,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn build_dispatches_all_mechanisms() {
        let mut rng = Xoshiro256::new(1);
        let q = ITensor::random(&[4, 4], -50, 50, &mut rng);
        let k = ITensor::random(&[4, 4], -50, 50, &mut rng);
        let v = ITensor::random(&[4, 4], -50, 50, &mut rng);
        for m in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
            let head = AttentionHead::build(AttnConfig::new(m, 4, 4), 0.05);
            let h = head.forward(&q, &k, &v);
            assert_eq!(h.dims(), &[4, 4]);
            assert_eq!(head.mechanism(), m);
        }
    }
}
