//! Quantized dot-product attention baseline (S2) — the comparator the
//! paper measures the Inhibitor against.
//!
//! Pipeline (all integer):
//!   1. `A = Q·Kᵀ` with i64 accumulation — the variable×variable products
//!      the Inhibitor removes; quantized scale is s², and the accumulator
//!      grows by log2(d) bits ("expansion to double precision").
//!   2. requantize by `1/(√d·s)` to score codes (literal multiply).
//!   3. integer Softmax via an exp **lookup table** over the score code
//!      space — faithfully mirroring how Softmax must be realised under
//!      TFHE (a PBS table per entry) and on LUT-based integer hardware.
//!   4. `H = P·V` with fixed-point probabilities (second matmul).
//!
//! The LUT uses the numerically-stable shifted form `exp(s_j − max_i s)`,
//! exactly as a Concrete circuit would (max, subtract, PBS, normalize).

use super::common::AttnConfig;
use crate::quant::FixedMult;
use crate::tensor::ITensor;

/// Fixed-point fraction bits for the softmax probabilities.
pub const SOFTMAX_FRAC_BITS: u32 = 16;

/// Integer softmax over score codes.
///
/// `scores[i][j]` are integer codes at scale `score_scale` (i.e. the real
/// logit is `code · score_scale`). Returns fixed-point probabilities with
/// `SOFTMAX_FRAC_BITS` fraction bits; every row sums to ≈ 2^FRAC.
pub struct IntSoftmax {
    /// exp(−x·score_scale)·2^FRAC for x = 0..table_len−1.
    table: Vec<i64>,
}

impl IntSoftmax {
    /// Build the LUT for a score code space of `score_bits` signed bits.
    /// The worst-case shifted argument max−s spans the full signed range,
    /// i.e. 2^score_bits distinct non-negative values — one PBS table of
    /// exactly that size in the TFHE realization (Table 2's wider "uint"
    /// column for the dot-product variant comes from here).
    pub fn new(score_bits: u32, score_scale: f32) -> Self {
        let len = 1usize << score_bits;
        let table = (0..len)
            .map(|x| {
                let e = (-(x as f64) * score_scale as f64).exp();
                (e * (1i64 << SOFTMAX_FRAC_BITS) as f64).round() as i64
            })
            .collect();
        IntSoftmax { table }
    }

    pub fn table_len(&self) -> usize {
        self.table.len()
    }

    /// Row-wise integer softmax: scores `[n, m]` → probabilities `[n, m]`
    /// in fixed point (2^FRAC ≈ 1.0).
    pub fn apply_rows(&self, scores: &ITensor) -> ITensor {
        let (n, m) = (scores.dims()[0], scores.dims()[1]);
        let mut out = ITensor::zeros(&[n, m]);
        for i in 0..n {
            let row = &scores.data[i * m..(i + 1) * m];
            let mx = *row.iter().max().expect("non-empty row");
            // e_j = LUT[max − s_j]; the shifted index is always ≥ 0.
            let mut es = vec![0i64; m];
            let mut sum = 0i64;
            for j in 0..m {
                let idx = (mx - row[j]) as usize;
                let e = self.table.get(idx).copied().unwrap_or(0);
                es[j] = e;
                sum += e;
            }
            let orow = &mut out.data[i * m..(i + 1) * m];
            if sum == 0 {
                // Degenerate: fall back to uniform (can only happen when the
                // LUT underflows everywhere, which the max-shift prevents for
                // the max element itself — table[0] = 2^FRAC — so never).
                let u = (1i64 << SOFTMAX_FRAC_BITS) / m as i64;
                orow.iter_mut().for_each(|p| *p = u);
            } else {
                for j in 0..m {
                    orow[j] = (es[j] << SOFTMAX_FRAC_BITS) / sum;
                }
            }
        }
        out
    }
}

/// Full quantized dot-product attention head.
pub struct DotProductHead {
    pub cfg: AttnConfig,
    /// Requant of the Q·Kᵀ accumulator (scale s²) to score codes.
    pub score_requant: FixedMult,
    pub softmax: IntSoftmax,
    /// Requant of the P·V accumulator (scale s·2^FRAC) back to code scale.
    pub out_requant: FixedMult,
}

impl DotProductHead {
    /// `code_scale` is the common Q/K/V input code scale; `score_bits` the
    /// signed width of the score code space (LUT size = 2^score_bits).
    pub fn from_config(cfg: AttnConfig, code_scale: f32, score_bits: u32) -> Self {
        let d = cfg.dim as f64;
        // Real logit = acc · s² / √d. Choose score_scale so the code range
        // covers ±(score range): score_code = acc · s²/√d / score_scale.
        // A good default: logits rarely exceed ~8 in trained models.
        let logit_max = 8.0f64;
        let score_scale = (logit_max / ((1i64 << (score_bits - 1)) - 1) as f64) as f32;
        let score_requant =
            FixedMult::from_f64(code_scale as f64 * code_scale as f64 / d.sqrt() / score_scale as f64);
        let softmax = IntSoftmax::new(score_bits, score_scale);
        // P (2^FRAC fixed point) × V (code scale) accumulates at
        // scale = code_scale / 2^FRAC ⇒ requant by 2^-FRAC to code scale.
        let out_requant = FixedMult::from_f64(1.0 / (1u64 << SOFTMAX_FRAC_BITS) as f64);
        DotProductHead { cfg, score_requant, softmax, out_requant }
    }

    /// Run the head: Q, K, V are `[n, d]` integer code tensors at the
    /// common code scale; output is at the same code scale.
    pub fn forward(&self, q: &ITensor, k: &ITensor, v: &ITensor) -> ITensor {
        let acc = q.matmul(&k.transpose2());
        let scores = acc.map(|x| self.score_requant.apply(x));
        let probs = self.softmax.apply_rows(&scores);
        let hv = probs.matmul(v);
        hv.map(|x| self.out_requant.apply(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::common::{ref_dotprod, Mechanism};
    use crate::quant::QParams;
    use crate::tensor::FTensor;
    use crate::util::prng::{Rng64, Xoshiro256};
    use crate::util::prop::{prop_assert, prop_check};

    #[test]
    fn int_softmax_rows_sum_to_one() {
        let sm = IntSoftmax::new(8, 0.0625);
        let scores = ITensor::from_vec(&[2, 4], vec![10, 20, 30, 40, -5, -5, -5, -5]);
        let p = sm.apply_rows(&scores);
        for i in 0..2 {
            let s: i64 = (0..4).map(|j| p.at2(i, j)).sum();
            let one = 1i64 << SOFTMAX_FRAC_BITS;
            assert!((s - one).abs() <= 4, "row {i} sums to {s}, want ≈ {one}");
        }
        // Monotone in the score.
        assert!(p.at2(0, 3) > p.at2(0, 0));
        // Uniform row stays uniform.
        assert_eq!(p.at2(1, 0), p.at2(1, 3));
    }

    #[test]
    fn int_softmax_tracks_float() {
        prop_check("int softmax ≈ float softmax", 64, |rng| {
            let m = 2 + rng.next_bounded(8) as usize;
            let scale = 0.05f32;
            let sm = IntSoftmax::new(8, scale);
            let codes = ITensor::random(&[1, m], -100, 100, rng);
            let p = sm.apply_rows(&codes);
            let f = FTensor::from_vec(&[1, m], codes.data.iter().map(|&c| c as f32 * scale).collect())
                .softmax_rows();
            for j in 0..m {
                let got = p.at2(0, j) as f32 / (1i64 << SOFTMAX_FRAC_BITS) as f32;
                let want = f.at2(0, j);
                prop_assert((got - want).abs() < 0.01, &format!("j={j} got={got} want={want}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn quantized_head_tracks_float_reference() {
        prop_check("int dotprod head ≈ float ref", 16, |rng| {
            let n = 2 + rng.next_bounded(6) as usize;
            let d = 2 + rng.next_bounded(6) as usize;
            let mut frng = Xoshiro256::new(rng.next_u64());
            let qf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let kf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let vf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let qp = QParams::fit_symmetric(4.0, 12);
            let cfg = AttnConfig::new(Mechanism::DotProduct, n, d);
            let head = DotProductHead::from_config(cfg, qp.scale, 10);
            let h_int = head.forward(
                &qp.quantize_tensor(&qf),
                &qp.quantize_tensor(&kf),
                &qp.quantize_tensor(&vf),
            );
            let h = qp.dequantize_tensor(&h_int);
            let want = ref_dotprod(&qf, &kf, &vf);
            // Output is a convex combination of V rows → error is O(scale)
            // plus softmax LUT error spread over V's range.
            let tol = 0.1f32.max(qp.scale * 8.0);
            let err = h.max_abs_diff(&want);
            prop_assert(err <= tol, &format!("err {err} > tol {tol} (n={n} d={d})"))
        });
    }

    #[test]
    fn one_hot_attention_selects_row() {
        // One query matching one key exactly with large margin → P ≈ onehot
        // → H ≈ that V row.
        let qp = QParams::fit_symmetric(8.0, 12);
        let q = FTensor::from_vec(&[1, 2], vec![4.0, 4.0]);
        let k = FTensor::from_vec(&[3, 2], vec![4.0, 4.0, -4.0, 4.0, 4.0, -4.0]);
        let v = FTensor::from_vec(&[3, 2], vec![1.0, 2.0, 5.0, 6.0, -3.0, -4.0]);
        let cfg = AttnConfig::new(Mechanism::DotProduct, 3, 2);
        let head = DotProductHead::from_config(cfg, qp.scale, 10);
        let h = qp.dequantize_tensor(&head.forward(
            &qp.quantize_tensor(&q),
            &qp.quantize_tensor(&k),
            &qp.quantize_tensor(&v),
        ));
        assert!((h.at2(0, 0) - 1.0).abs() < 0.3, "{}", h.at2(0, 0));
        assert!((h.at2(0, 1) - 2.0).abs() < 0.3, "{}", h.at2(0, 1));
    }

    #[test]
    fn lut_size_matches_score_bits() {
        assert_eq!(IntSoftmax::new(7, 0.1).table_len(), 128);
        assert_eq!(IntSoftmax::new(4, 0.1).table_len(), 16);
    }
}
