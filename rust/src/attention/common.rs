//! Shared attention types + float reference implementations.
//!
//! The float references are the ground truth the quantized and encrypted
//! engines are tested against. They mirror `python/compile/kernels/ref.py`
//! exactly (same equations, same constants), which ties the Rust request
//! path to the JAX build path numerically.

use crate::tensor::{FTensor, ITensor};

/// Which attention mechanism a head runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mechanism {
    /// Conventional scaled dot-product + Softmax (paper eq. 3).
    DotProduct,
    /// Inhibitor: Manhattan score + subtract-and-ReLU (paper eqs. 5–6).
    Inhibitor,
    /// Signed inhibitor (paper eq. 7 / appendix).
    InhibitorSigned,
}

impl Mechanism {
    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::DotProduct => "dotprod",
            Mechanism::Inhibitor => "inhibitor",
            Mechanism::InhibitorSigned => "inhibitor-signed",
        }
    }

    pub fn parse(s: &str) -> Option<Mechanism> {
        match s {
            "dotprod" | "dot-product" | "softmax" => Some(Mechanism::DotProduct),
            "inhibitor" => Some(Mechanism::Inhibitor),
            "inhibitor-signed" | "signed" => Some(Mechanism::InhibitorSigned),
            _ => None,
        }
    }
}

/// Column split of a `d_model`-wide activation into `n_heads` head
/// slices — the single definition of per-head slicing arithmetic shared
/// by the plaintext block (`model::Block`), the fused multi-head mirror
/// (`fhe_circuits::MultiHeadFhe`), the encrypted block circuit
/// (`fhe_circuits::BlockFhe`) and the block profiler
/// (`optimizer::precision::profile_block`), so the four can never drift
/// on how a model width maps to head columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadSplit {
    pub d_model: usize,
    pub n_heads: usize,
}

impl HeadSplit {
    /// Panics unless `d_model` splits evenly into `n_heads ≥ 1` slices.
    pub fn new(d_model: usize, n_heads: usize) -> Self {
        assert!(n_heads >= 1, "a multi-head split needs at least one head");
        assert_eq!(d_model % n_heads, 0, "width {d_model} must split into {n_heads} heads");
        HeadSplit { d_model, n_heads }
    }

    /// Per-head slice width d = D / H.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// First column of head `h`'s slice.
    pub fn col0(&self, h: usize) -> usize {
        assert!(h < self.n_heads, "head {h} out of {} heads", self.n_heads);
        h * self.d_head()
    }

    /// Multi-head attention over column slices: apply `f` to each head's
    /// Q slice (and its K/V slices, or the full `k`/`v` tensors under a
    /// shared-KV / multi-query layout) and concatenate the per-head
    /// outputs back into `[T, d_model]` column order.
    pub fn apply(
        &self,
        q: &ITensor,
        k: &ITensor,
        v: &ITensor,
        shared_kv: bool,
        mut f: impl FnMut(&ITensor, &ITensor, &ITensor) -> ITensor,
    ) -> ITensor {
        assert_eq!(q.dims()[1], self.d_model, "q width must be the split's d_model");
        let d = self.d_head();
        let parts: Vec<ITensor> = (0..self.n_heads)
            .map(|h| {
                let qs = q.slice_cols(self.col0(h), d);
                if shared_kv {
                    f(&qs, k, v)
                } else {
                    f(&qs, &k.slice_cols(self.col0(h), d), &v.slice_cols(self.col0(h), d))
                }
            })
            .collect();
        let refs: Vec<&ITensor> = parts.iter().collect();
        ITensor::concat_cols(&refs)
    }
}

/// Attention hyper-parameters shared by all engines.
#[derive(Clone, Copy, Debug)]
pub struct AttnConfig {
    pub mechanism: Mechanism,
    /// Sequence length n.
    pub seq_len: usize,
    /// Head dimension d.
    pub dim: usize,
    /// Shifted-score offset α ≥ 0 (paper: α = 0.5). Applied as
    /// Z' = (Z − α)⁺; 0 disables the shift.
    pub alpha: f32,
    /// Score scale γ (paper: γ = √d). Values ≤ 0 mean "use √d".
    pub gamma: f32,
}

impl AttnConfig {
    pub fn new(mechanism: Mechanism, seq_len: usize, dim: usize) -> Self {
        AttnConfig { mechanism, seq_len, dim, alpha: 0.5, gamma: -1.0 }
    }

    pub fn effective_gamma(&self) -> f32 {
        if self.gamma > 0.0 {
            self.gamma
        } else {
            (self.dim as f32).sqrt()
        }
    }
}

/// Float reference: conventional attention, eq. 3 + H = S·V.
pub fn ref_dotprod(q: &FTensor, k: &FTensor, v: &FTensor) -> FTensor {
    let d = q.dims()[1] as f32;
    let scores = q.matmul(&k.transpose2()).map(|x| x / d.sqrt());
    scores.softmax_rows().matmul(v)
}

/// Float reference: Manhattan inhibition score, eq. 5 (+ optional shift).
pub fn ref_inhibitor_scores(q: &FTensor, k: &FTensor, gamma: f32, alpha: f32) -> FTensor {
    let (n, d) = (q.dims()[0], q.dims()[1]);
    let m = k.dims()[0];
    let mut z = FTensor::zeros(&[n, m]);
    for i in 0..n {
        for j in 0..m {
            let mut s = 0.0f32;
            for kk in 0..d {
                s += (q.at2(i, kk) - k.at2(j, kk)).abs();
            }
            let zi = s / gamma;
            z.data[i * m + j] = (zi - alpha).max(0.0); // shifted score Z'
        }
    }
    z
}

/// Float reference: unsigned inhibition, eq. 6.
pub fn ref_inhibitor(q: &FTensor, k: &FTensor, v: &FTensor, gamma: f32, alpha: f32) -> FTensor {
    let z = ref_inhibitor_scores(q, k, gamma, alpha);
    let (n, m) = (z.dims()[0], z.dims()[1]);
    let dv = v.dims()[1];
    let mut h = FTensor::zeros(&[n, dv]);
    for i in 0..n {
        for kk in 0..dv {
            let mut s = 0.0f32;
            for j in 0..m {
                s += (v.at2(j, kk) - z.at2(i, j)).max(0.0);
            }
            h.data[i * dv + kk] = s;
        }
    }
    h
}

/// Float reference: signed inhibition, eq. 7.
pub fn ref_inhibitor_signed(
    q: &FTensor,
    k: &FTensor,
    v: &FTensor,
    gamma: f32,
    alpha: f32,
) -> FTensor {
    let z = ref_inhibitor_scores(q, k, gamma, alpha);
    let (n, m) = (z.dims()[0], z.dims()[1]);
    let dv = v.dims()[1];
    let mut h = FTensor::zeros(&[n, dv]);
    for i in 0..n {
        for kk in 0..dv {
            let mut s = 0.0f32;
            for j in 0..m {
                let vp = v.at2(j, kk).max(0.0);
                let vn = v.at2(j, kk).min(0.0);
                s += (vp - z.at2(i, j)).max(0.0) + (vn + z.at2(i, j)).min(0.0);
            }
            h.data[i * dv + kk] = s;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn mechanism_parse_roundtrip() {
        for m in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
            assert_eq!(Mechanism::parse(m.name()), Some(m));
        }
        assert_eq!(Mechanism::parse("nonsense"), None);
    }

    #[test]
    fn head_split_slices_and_concatenates_column_wise() {
        let mut rng = Xoshiro256::new(3);
        let split = HeadSplit::new(6, 3);
        assert_eq!(split.d_head(), 2);
        assert_eq!(split.col0(2), 4);
        let q = ITensor::random(&[4, 6], -5, 5, &mut rng);
        let k = ITensor::random(&[4, 6], -5, 5, &mut rng);
        let v = ITensor::random(&[4, 6], -5, 5, &mut rng);
        // f = per-slice V passthrough → apply must reassemble V exactly.
        let got = split.apply(&q, &k, &v, false, |_q, _k, vs| vs.clone());
        assert_eq!(got, v);
        // Shared-KV layout: every head sees the full k/v tensors.
        let kv = ITensor::random(&[4, 2], -5, 5, &mut rng);
        let got = split.apply(&q, &kv, &kv, true, |qs, ks, _vs| {
            assert_eq!(ks.dims(), &[4, 2]);
            qs.clone()
        });
        assert_eq!(got, q, "shared-KV apply reassembles the per-head Q slices");
    }

    #[test]
    #[should_panic(expected = "must split")]
    fn head_split_rejects_uneven_widths() {
        let _ = HeadSplit::new(5, 2);
    }

    #[test]
    fn zero_score_passes_values_through_unsigned() {
        // If Q == K (score 0 after shift α ≥ 0) and V ≥ 0, every row of H
        // is the column-sum of V: Σ_j (V_jk − 0)⁺ = Σ_j V_jk.
        let q = FTensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let v = FTensor::from_vec(&[2, 2], vec![0.5, 1.0, 2.0, 0.25]);
        let h = ref_inhibitor(&q, &q, &v, 1.0, 0.5);
        for i in 0..2 {
            assert!((h.at2(i, 0) - 2.5).abs() < 1e-6);
            assert!((h.at2(i, 1) - 1.25).abs() < 1e-6);
        }
    }

    #[test]
    fn signed_reduces_to_unsigned_for_nonneg_values() {
        let mut rng = Xoshiro256::new(5);
        let q = FTensor::randn(&[6, 4], 1.0, &mut rng);
        let k = FTensor::randn(&[6, 4], 1.0, &mut rng);
        let v = FTensor::randn(&[6, 4], 1.0, &mut rng).map(|x| x.abs());
        let a = ref_inhibitor(&q, &k, &v, 2.0, 0.5);
        let b = ref_inhibitor_signed(&q, &k, &v, 2.0, 0.5);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn large_distance_inhibits_everything() {
        // Keys far from queries → huge Z → H = 0 (unsigned, bounded V).
        let q = FTensor::from_vec(&[1, 2], vec![0.0, 0.0]);
        let k = FTensor::from_vec(&[2, 2], vec![100.0, 100.0, 80.0, 90.0]);
        let v = FTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let h = ref_inhibitor(&q, &k, &v, 1.0, 0.0);
        assert_eq!(h.data, vec![0.0, 0.0]);
    }

    #[test]
    fn signed_inhibition_extinguishes_both_signs() {
        let q = FTensor::from_vec(&[1, 2], vec![0.0, 0.0]);
        let k = FTensor::from_vec(&[2, 2], vec![100.0, 100.0, 80.0, 90.0]);
        let v = FTensor::from_vec(&[2, 2], vec![-1.0, 2.0, 3.0, -4.0]);
        let h = ref_inhibitor_signed(&q, &k, &v, 1.0, 0.0);
        assert_eq!(h.data, vec![0.0, 0.0]);
    }

    #[test]
    fn dotprod_reference_rows_are_convex_combinations() {
        let mut rng = Xoshiro256::new(9);
        let q = FTensor::randn(&[4, 3], 1.0, &mut rng);
        let k = FTensor::randn(&[4, 3], 1.0, &mut rng);
        let v = FTensor::randn(&[4, 3], 1.0, &mut rng);
        let h = ref_dotprod(&q, &k, &v);
        let (vmin, vmax) = (v.min(), v.max());
        for &x in &h.data {
            assert!((vmin - 1e-4..=vmax + 1e-4).contains(&x));
        }
    }

    #[test]
    fn effective_gamma_defaults_to_sqrt_d() {
        let c = AttnConfig::new(Mechanism::Inhibitor, 8, 16);
        assert!((c.effective_gamma() - 4.0).abs() < 1e-6);
        let mut c2 = c;
        c2.gamma = 3.0;
        assert_eq!(c2.effective_gamma(), 3.0);
    }
}
