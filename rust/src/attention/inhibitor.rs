//! Quantized Inhibitor attention (S2) — the paper's contribution.
//!
//! All arithmetic is integer: |Q−K| Manhattan scores (eq. 5), the shift
//! α (quantized to the score scale), and the subtract-and-ReLU inhibition
//! (eq. 6, signed variant eq. 7). Two implementations are provided:
//!
//! * [`inhibitor_attention_naive`] — literal transcription of eqs. 5–7
//!   (broadcast, ReLU, sum). Used as the in-crate oracle.
//! * [`inhibitor_attention`] — the fused form of appendix eqs. 9–10:
//!   `Σ_j (V_jk − Z_ij)⁺ = ½(Σ_j V_jk − Σ_j Z_ij + Σ_j |V_jk − Z_ij|)`
//!   which keeps the working set at O(n·m) and exposes the pairwise-|·|
//!   reduction that fused cdist kernels (and our Pallas kernel) implement.
//!
//! The halving in eqs. 9–10 is exact in integers when performed once on
//! the final accumulated sum *if* the sum is even; to stay exact we keep
//! the doubled accumulator `2·H` and fold the ÷2 into the output
//! requantization factor (a literal multiplication — cheap everywhere,
//! including under TFHE).

use super::common::AttnConfig;
use crate::quant::FixedMult;
use crate::tensor::ITensor;

/// Integer inhibition scores, eq. 5 with scale γ and shift α folded in:
/// `Z_ij = ((Σ_k |Q_ik − K_jk|) · (1/γ) − α_q)⁺` where the 1/γ literal is
/// applied by fixed-point requantization and α_q is α quantized to the
/// score scale. If `alpha_q == 0` the ReLU is skipped (plain eq. 5).
pub fn inhibitor_scores(q: &ITensor, k: &ITensor, inv_gamma: FixedMult, alpha_q: i64) -> ITensor {
    let raw = q.manhattan_cdist(k);
    let mut z = ITensor { shape: raw.shape.clone(), data: Vec::with_capacity(raw.data.len()) };
    if alpha_q > 0 {
        z.data.extend(raw.data.iter().map(|&x| (inv_gamma.apply(x) - alpha_q).max(0)));
    } else {
        z.data.extend(raw.data.iter().map(|&x| inv_gamma.apply(x)));
    }
    z
}

/// Naive unsigned inhibition (eq. 6): `H_ik = Σ_j (V_jk − Z_ij)⁺`.
pub fn inhibit_naive(z: &ITensor, v: &ITensor) -> ITensor {
    let (n, m) = (z.dims()[0], z.dims()[1]);
    let (m2, dv) = (v.dims()[0], v.dims()[1]);
    assert_eq!(m, m2, "Z and V disagree on sequence length");
    let mut h = ITensor::zeros(&[n, dv]);
    for i in 0..n {
        for kk in 0..dv {
            let mut s = 0i64;
            for j in 0..m {
                s += (v.at2(j, kk) - z.at2(i, j)).max(0);
            }
            h.data[i * dv + kk] = s;
        }
    }
    h
}

/// Naive signed inhibition (eq. 7).
pub fn inhibit_signed_naive(z: &ITensor, v: &ITensor) -> ITensor {
    let (n, m) = (z.dims()[0], z.dims()[1]);
    let dv = v.dims()[1];
    assert_eq!(m, v.dims()[0]);
    let mut h = ITensor::zeros(&[n, dv]);
    for i in 0..n {
        for kk in 0..dv {
            let mut s = 0i64;
            for j in 0..m {
                let vjk = v.at2(j, kk);
                let (vp, vn) = (vjk.max(0), vjk.min(0));
                s += (vp - z.at2(i, j)).max(0) + (vn + z.at2(i, j)).min(0);
            }
            h.data[i * dv + kk] = s;
        }
    }
    h
}

/// Fused unsigned inhibition, eq. 9, returning the **doubled** result
/// `2·H_ik = Σ_j V_jk − Σ_j Z_ij + Σ_j |V_jk − Z_ij|` (exact in integers).
pub fn inhibit_fused_x2(z: &ITensor, v: &ITensor) -> ITensor {
    let (n, m) = (z.dims()[0], z.dims()[1]);
    let dv = v.dims()[1];
    assert_eq!(m, v.dims()[0]);
    // Column sums of V: Σ_j V_jk  (k-indexed).
    let v_colsum = v.sum_axis2(0);
    // Row sums of Z: Σ_j Z_ij  (i-indexed).
    let z_rowsum = z.sum_axis2(1);
    let mut h = ITensor::zeros(&[n, dv]);
    for i in 0..n {
        let zrow = &z.data[i * m..(i + 1) * m];
        let hrow = &mut h.data[i * dv..(i + 1) * dv];
        // |V_jk − Z_ij| accumulated per k, streaming over j (V row-major).
        for (j, &zij) in zrow.iter().enumerate() {
            let vrow = &v.data[j * dv..(j + 1) * dv];
            for (acc, &vjk) in hrow.iter_mut().zip(vrow.iter()) {
                *acc += (vjk - zij).abs();
            }
        }
        for (kk, acc) in hrow.iter_mut().enumerate() {
            *acc += v_colsum[kk] - z_rowsum[i];
        }
    }
    h
}

/// Fused signed inhibition, eq. 10, returning the doubled result
/// `2·H_ik = Σ_j V_jk + Σ_j |V⁺_jk − Z_ij| − Σ_j |V⁻_jk + Z_ij|`.
pub fn inhibit_signed_fused_x2(z: &ITensor, v: &ITensor) -> ITensor {
    let (n, m) = (z.dims()[0], z.dims()[1]);
    let dv = v.dims()[1];
    assert_eq!(m, v.dims()[0]);
    let v_colsum = v.sum_axis2(0);
    // Pre-split V once (reused across all query rows).
    let vp: Vec<i64> = v.data.iter().map(|&x| x.max(0)).collect();
    let vn: Vec<i64> = v.data.iter().map(|&x| x.min(0)).collect();
    let mut h = ITensor::zeros(&[n, dv]);
    for i in 0..n {
        let zrow = &z.data[i * m..(i + 1) * m];
        let hrow = &mut h.data[i * dv..(i + 1) * dv];
        for (j, &zij) in zrow.iter().enumerate() {
            let vprow = &vp[j * dv..(j + 1) * dv];
            let vnrow = &vn[j * dv..(j + 1) * dv];
            for kk in 0..dv {
                hrow[kk] += (vprow[kk] - zij).abs() - (vnrow[kk] + zij).abs();
            }
        }
        for (kk, acc) in hrow.iter_mut().enumerate() {
            *acc += v_colsum[kk];
        }
    }
    h
}

/// Full quantized Inhibitor attention head.
///
/// Inputs are integer codes at a common scale `s`; `inv_gamma` carries the
/// 1/γ literal; `alpha_q` is α quantized to the score scale; `out_requant`
/// maps the doubled accumulator `2·H` back to code scale (so it should
/// embed the extra factor ½).
pub struct InhibitorHead {
    pub cfg: AttnConfig,
    pub inv_gamma: FixedMult,
    pub alpha_q: i64,
    pub out_requant: FixedMult,
    pub signed: bool,
}

impl InhibitorHead {
    /// Build a head from an `AttnConfig` and the common input code scale.
    pub fn from_config(cfg: AttnConfig, code_scale: f32, signed: bool) -> Self {
        let gamma = cfg.effective_gamma();
        // Scores share the input scale after the 1/γ literal; α quantizes
        // to the same scale.
        let alpha_q = (cfg.alpha / code_scale).round() as i64;
        InhibitorHead {
            cfg,
            inv_gamma: FixedMult::from_f64(1.0 / gamma as f64),
            alpha_q,
            // ÷2 for the doubled fused accumulator; output stays at the
            // common code scale (sums over the sequence can grow the range;
            // the model layer handles that with its own requant).
            out_requant: FixedMult::from_f64(0.5),
            signed,
        }
    }

    /// Run the head: Q, K, V are `[n, d]` integer code tensors.
    pub fn forward(&self, q: &ITensor, k: &ITensor, v: &ITensor) -> ITensor {
        let z = inhibitor_scores(q, k, self.inv_gamma, self.alpha_q);
        let h2 = if self.signed {
            inhibit_signed_fused_x2(&z, v)
        } else {
            inhibit_fused_x2(&z, v)
        };
        h2.map(|x| self.out_requant.apply(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::common::{ref_inhibitor, ref_inhibitor_signed, Mechanism};
    use crate::quant::QParams;
    use crate::tensor::FTensor;
    use crate::util::prng::{Rng64, Xoshiro256};
    use crate::util::prop::{prop_assert, prop_assert_eq, prop_check};

    #[test]
    fn fused_matches_naive_unsigned() {
        prop_check("eq9 fused == eq6 naive (x2)", 64, |rng| {
            let n = 1 + rng.next_bounded(8) as usize;
            let m = 1 + rng.next_bounded(8) as usize;
            let dv = 1 + rng.next_bounded(6) as usize;
            let z = ITensor::random(&[n, m], 0, 60, rng);
            let v = ITensor::random(&[m, dv], -40, 40, rng);
            let naive = inhibit_naive(&z, &v).scalar_mul(2);
            let fused = inhibit_fused_x2(&z, &v);
            prop_assert_eq(fused, naive, "fused vs naive")
        });
    }

    #[test]
    fn fused_matches_naive_signed() {
        prop_check("eq10 fused == eq7 naive (x2)", 64, |rng| {
            let n = 1 + rng.next_bounded(8) as usize;
            let m = 1 + rng.next_bounded(8) as usize;
            let dv = 1 + rng.next_bounded(6) as usize;
            let z = ITensor::random(&[n, m], 0, 60, rng);
            let v = ITensor::random(&[m, dv], -40, 40, rng);
            let naive = inhibit_signed_naive(&z, &v).scalar_mul(2);
            let fused = inhibit_signed_fused_x2(&z, &v);
            prop_assert_eq(fused, naive, "fused vs naive signed")
        });
    }

    #[test]
    fn scores_shift_clamps_at_zero() {
        let q = ITensor::from_vec(&[1, 2], vec![3, 3]);
        let k = ITensor::from_vec(&[2, 2], vec![3, 3, 4, 5]);
        let z = inhibitor_scores(&q, &k, FixedMult::from_f64(1.0), 2);
        // distances: 0 and 3; shifted by 2 → 0 and 1.
        assert_eq!(z.data, vec![0, 1]);
    }

    #[test]
    fn scores_without_shift_are_plain_distance() {
        let q = ITensor::from_vec(&[1, 2], vec![0, 0]);
        let k = ITensor::from_vec(&[1, 2], vec![5, -7]);
        let z = inhibitor_scores(&q, &k, FixedMult::from_f64(1.0), 0);
        assert_eq!(z.data, vec![12]);
    }

    #[test]
    fn quantized_head_tracks_float_reference() {
        // End-to-end: quantize float Q/K/V, run the integer head, compare to
        // the float reference within a quantization-error bound.
        prop_check("int head ≈ float ref", 24, |rng| {
            let n = 2 + rng.next_bounded(6) as usize;
            let d = 2 + rng.next_bounded(6) as usize;
            let mut frng = Xoshiro256::new(rng.next_u64());
            let qf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let kf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let vf = FTensor::randn(&[n, d], 1.0, &mut frng).map(|x| x.abs());
            let qp = QParams::fit_symmetric(4.0, 12);
            let cfg = AttnConfig::new(Mechanism::Inhibitor, n, d);
            let head = InhibitorHead::from_config(cfg, qp.scale, false);
            let h_int = head.forward(
                &qp.quantize_tensor(&qf),
                &qp.quantize_tensor(&kf),
                &qp.quantize_tensor(&vf),
            );
            let h = qp.dequantize_tensor(&h_int);
            let want = ref_inhibitor(&qf, &kf, &vf, cfg.effective_gamma(), cfg.alpha);
            // Error budget: n terms, each with O(scale) rounding error from
            // (d+1) quantized operands plus the score requant.
            let tol = qp.scale * (n as f32) * (d as f32 + 3.0);
            let err = h.max_abs_diff(&want);
            prop_assert(err <= tol, &format!("err {err} > tol {tol} (n={n}, d={d})"))
        });
    }

    #[test]
    fn quantized_signed_head_tracks_float_reference() {
        prop_check("int signed head ≈ float ref", 24, |rng| {
            let n = 2 + rng.next_bounded(6) as usize;
            let d = 2 + rng.next_bounded(6) as usize;
            let mut frng = Xoshiro256::new(rng.next_u64());
            let qf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let kf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let vf = FTensor::randn(&[n, d], 1.0, &mut frng);
            let qp = QParams::fit_symmetric(4.0, 12);
            let cfg = AttnConfig::new(Mechanism::InhibitorSigned, n, d);
            let head = InhibitorHead::from_config(cfg, qp.scale, true);
            let h_int = head.forward(
                &qp.quantize_tensor(&qf),
                &qp.quantize_tensor(&kf),
                &qp.quantize_tensor(&vf),
            );
            let h = qp.dequantize_tensor(&h_int);
            let want = ref_inhibitor_signed(&qf, &kf, &vf, cfg.effective_gamma(), cfg.alpha);
            let tol = qp.scale * (n as f32) * (d as f32 + 3.0);
            let err = h.max_abs_diff(&want);
            prop_assert(err <= tol, &format!("err {err} > tol {tol} (n={n}, d={d})"))
        });
    }

    #[test]
    fn identical_query_key_passes_nonneg_values() {
        // Z = 0 (with α ≥ 0 shift) ⇒ H row = column sums of V.
        let n = 3;
        let q = ITensor::from_vec(&[n, 2], vec![7, -2, 7, -2, 7, -2]);
        let v = ITensor::from_vec(&[n, 2], vec![1, 2, 3, 4, 5, 6]);
        let cfg = AttnConfig::new(Mechanism::Inhibitor, n, 2);
        let head = InhibitorHead::from_config(cfg, 0.05, false);
        let h = head.forward(&q, &q, &v);
        for i in 0..n {
            assert_eq!(h.at2(i, 0), 9);
            assert_eq!(h.at2(i, 1), 12);
        }
    }
}
