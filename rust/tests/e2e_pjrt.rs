//! E2E: AOT artifacts (L1 Pallas kernels lowered via L2 jax) executed
//! through the PJRT runtime must match the in-crate float references —
//! this pins the numerical contract between the Python build path and the
//! Rust request path.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent.
//! The whole file needs the PJRT runtime, which is behind the `xla`
//! cargo feature (vendored xla crate) — without it this test binary is
//! empty.
#![cfg(feature = "xla")]

use inhibitor::attention::common;
use inhibitor::runtime::Registry;
use inhibitor::tensor::FTensor;
use inhibitor::util::prng::Xoshiro256;

fn registry() -> Option<Registry> {
    match Registry::open("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping PJRT e2e: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn rand_mats(t: usize, d: usize, seed: u64) -> (FTensor, FTensor, FTensor) {
    let mut rng = Xoshiro256::new(seed);
    (
        FTensor::randn(&[t, d], 1.0, &mut rng),
        FTensor::randn(&[t, d], 1.0, &mut rng),
        FTensor::randn(&[t, d], 1.0, &mut rng),
    )
}

#[test]
fn pallas_inhibitor_artifact_matches_rust_reference() {
    let Some(mut reg) = registry() else { return };
    for t in [32usize, 64] {
        let engine = reg.attention_engine("inhibitor", t).expect("artifact");
        let (q, k, v) = rand_mats(t, 64, t as u64);
        let out = engine
            .run_f32(&[q.data.clone(), k.data.clone(), v.data.clone()])
            .expect("execute");
        let want = common::ref_inhibitor(&q, &k, &v, (64f32).sqrt(), 0.5);
        assert_eq!(out.len(), want.data.len());
        let max_err = out
            .iter()
            .zip(want.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "T={t}: max err {max_err}");
    }
}

#[test]
fn pallas_signed_inhibitor_artifact_matches_rust_reference() {
    let Some(mut reg) = registry() else { return };
    let t = 32;
    let engine = reg.attention_engine("inhibitor-signed", t).expect("artifact");
    let (q, k, v) = rand_mats(t, 64, 99);
    let out = engine
        .run_f32(&[q.data.clone(), k.data.clone(), v.data.clone()])
        .expect("execute");
    let want = common::ref_inhibitor_signed(&q, &k, &v, (64f32).sqrt(), 0.5);
    let max_err = out
        .iter()
        .zip(want.data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn pallas_dotprod_artifact_matches_rust_reference() {
    let Some(mut reg) = registry() else { return };
    let t = 32;
    let engine = reg.attention_engine("dotprod", t).expect("artifact");
    let (q, k, v) = rand_mats(t, 64, 7);
    let out = engine
        .run_f32(&[q.data.clone(), k.data.clone(), v.data.clone()])
        .expect("execute");
    let want = common::ref_dotprod(&q, &k, &v);
    let max_err = out
        .iter()
        .zip(want.data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn full_model_artifact_executes_with_manifest_shapes() {
    let Some(mut reg) = registry() else { return };
    let engine = reg.model_engine("model_inhibitor").expect("model artifact");
    let x = vec![0.1f32; 16 * 2];
    let out = engine.run_f32(&[x]).expect("execute");
    assert_eq!(out.len(), 1, "regression head returns one value");
    assert!(out[0].is_finite());
}

#[test]
fn engine_rejects_wrong_input_arity_and_shape() {
    let Some(mut reg) = registry() else { return };
    let engine = reg.attention_engine("inhibitor", 32).expect("artifact");
    assert!(engine.run_f32(&[vec![0.0; 32 * 64]]).is_err(), "arity check");
    assert!(
        engine
            .run_f32(&[vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]])
            .is_err(),
        "shape check"
    );
}
