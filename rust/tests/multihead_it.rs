//! Cross-head differential test harness for multi-head encrypted
//! attention (`fhe_circuits::MultiHeadFhe`).
//!
//! * **Differential grid**: over a seeded (H, T, d) × {inhibitor,
//!   inhibitor-signed, dotprod} × {per-head KV, shared KV} grid, the
//!   encrypted fused H-head forward must decode **bit-identical** to the
//!   plaintext quantized multi-head reference (per-head mirror on column
//!   slices, concatenated), with rewrites off (raw builder plan) *and*
//!   on (full pipeline), at 1 and 4 PBS worker threads, with every
//!   `PBS_COUNT`/`BLIND_ROTATION_COUNT` delta matching the executed
//!   plan's own prediction. The `forward()` path (cached `plan_for`,
//!   which honors `FHE_NO_REWRITE`) is exercised on every point, so the
//!   CI no-rewrite leg drives the unrewritten pipeline end-to-end here.
//! * **Count pinning**: the fused plan's closed forms, exact per shape —
//!   and the cross-head win: at `many_lut_log ≥ 1` the fused shared-KV
//!   signed plan needs **strictly fewer** blind rotations than H
//!   separately-rewritten single-head plans.
//! * **By-ref execution**: `forward()` performs zero `CtInt` clones for
//!   circuits whose inputs only feed linear nodes — the regression test
//!   for the "stop copying the 3·T·d·H inputs" hot path.
//! * **Serving**: co-scheduled multi-head requests ride the router's
//!   fused level executor and come back bit-identical to solo plan
//!   execution.
//!
//! Counters (`PBS_COUNT`, `BLIND_ROTATION_COUNT`, `ct_clone_count`) are
//! process-global and libtest runs tests on parallel threads, so every
//! test serializes through one lock.

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{BatchPolicy, Coordinator, EnginePath, Payload, RoutePolicy};
use inhibitor::fhe_circuits::{CtMatrix, InhibitorSignedFhe, MultiHeadFhe};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    bootstrap, ct_clone_count, ClientKey, FheContext, PlanRewriter, RewriteConfig, TfheParams,
};
use inhibitor::util::prng::Xoshiro256;
use std::sync::Mutex;
use std::time::Duration;

static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// One differential grid point: random Q/K/V in hand-sized ranges (every
/// linear intermediate provably fits the keyset's signed code range, so
/// mirror equality is exact, not probabilistic), executed through the
/// raw plan, the fully-rewritten plan, and `forward()`, at 1 and 4
/// worker threads, with plan-predicted counter deltas.
#[allow(clippy::too_many_arguments)]
fn check_point(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    mech: Mechanism,
    heads: usize,
    t: usize,
    d: usize,
    shared_kv: bool,
    qk_range: (i64, i64),
    v_range: (i64, i64),
) {
    let tag = format!("{mech:?} H={heads} T={t} d={d} shared={shared_kv}");
    let mh = MultiHeadFhe::new(mech, d, heads, shared_kv);
    let d_model = heads * d;
    let kv_cols = if shared_kv { d } else { d_model };
    let q = ITensor::random(&[t, d_model], qk_range.0, qk_range.1, rng);
    let k = ITensor::random(&[t, kv_cols], qk_range.0, qk_range.1, rng);
    let v = ITensor::random(&[t, kv_cols], v_range.0, v_range.1, rng);
    let cq = CtMatrix::encrypt(&q, ctx, ck, rng);
    let ckk = CtMatrix::encrypt(&k, ctx, ck, rng);
    let cv = CtMatrix::encrypt(&v, ctx, ck, rng);
    let want = mh.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
    let raw = mh.plan(t, d);
    let (rewritten, _) = PlanRewriter::for_ctx(ctx).rewrite(mh.plan(t, d));
    let refs = mh.input_refs(&cq, &ckk, &cv);
    for threads in [1usize, 4] {
        ctx.set_threads(threads);
        for (label, plan) in [("raw", &raw), ("rewritten", &rewritten)] {
            let before_pbs = bootstrap::pbs_count();
            let before_rot = bootstrap::blind_rotation_count();
            let outs = plan.execute_ref(ctx, &refs);
            assert_eq!(
                bootstrap::pbs_count() - before_pbs,
                plan.pbs_count(),
                "{tag} {label} threads={threads}: PBS delta"
            );
            assert_eq!(
                bootstrap::blind_rotation_count() - before_rot,
                plan.blind_rotation_count(),
                "{tag} {label} threads={threads}: rotation delta"
            );
            let got: Vec<i64> = outs.iter().map(|c| ctx.decrypt(c, ck)).collect();
            assert_eq!(got, want.data, "{tag} {label} threads={threads}: mirror equality");
        }
        // The serving path: cached plan_for (honors FHE_NO_REWRITE, so
        // the CI matrix leg drives the unrewritten pipeline through
        // here) — same decode either way.
        let fwd = mh.forward(ctx, &cq, &ckk, &cv);
        assert_eq!((fwd.rows, fwd.cols), (t, d_model), "{tag}: forward shape");
        assert_eq!(fwd.decrypt(ctx, ck), want, "{tag} forward threads={threads}");
    }
}

#[test]
fn multihead_inhibitor_matches_plaintext_reference_over_grid() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x31AD01);
    // Ranges: |q−k| ≤ 4 → dist ≤ 8 → z ≤ 5; (v−z)⁺ ≤ 3 summed over
    // T ≤ 3 → H ≤ 9: all within the 5-bit signed range [−16, 15].
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    for &(heads, t, d, shared) in
        &[(2usize, 2usize, 2usize, false), (2, 3, 2, true), (3, 2, 1, false)]
    {
        check_point(
            &ctx,
            &ck,
            &mut rng,
            Mechanism::Inhibitor,
            heads,
            t,
            d,
            shared,
            (-2, 2),
            (0, 3),
        );
    }
}

#[test]
fn multihead_signed_inhibitor_matches_plaintext_reference_over_grid() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x31AD02);
    // Packing-capable keyset (ϑ = 1 at 4 bits): the fused shared-KV
    // points execute genuinely packed cross-head rotations. Ranges per
    // T keep every interleaved partial sum within [−8, 7] (same
    // derivation as tests/rewrite_it.rs).
    let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    assert_eq!(ctx.max_multi_lut(), 2);
    for &(heads, t, d, shared, qk, v) in &[
        (2usize, 2usize, 2usize, false, (-2i64, 1i64), (-3i64, 3i64)),
        (2, 2, 2, true, (-2, 1), (-3, 3)),
        (3, 2, 2, true, (-1, 1), (-2, 2)),
    ] {
        check_point(&ctx, &ck, &mut rng, Mechanism::InhibitorSigned, heads, t, d, shared, qk, v);
    }
}

#[test]
fn multihead_dotprod_matches_plaintext_reference_over_grid() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x31AD03);
    // 6-bit range [−32, 31]; |q|,|k| ≤ 1 and v ∈ [−1, 2] bound every
    // intermediate: scores ≤ 2, e ∈ [3, 7], row sums ≤ 14 → r = 1,
    // p ≤ 7, square-LUT operands ≤ 9 (so (x²/4) ≤ 20), attend
    // accumulators ∈ [−14, 28].
    let ck = ClientKey::generate(TfheParams::test_for_bits(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    for &(heads, shared) in &[(2usize, false), (2, true)] {
        check_point(
            &ctx,
            &ck,
            &mut rng,
            Mechanism::DotProduct,
            heads,
            2,
            2,
            shared,
            (-1, 1),
            (-1, 2),
        );
    }
}

#[test]
fn fused_multihead_counts_follow_closed_forms() {
    // Pure DAG analysis — no crypto — so the sweep can be wide.
    let _g = lock();
    for &(heads, t, d) in &[(2usize, 2usize, 2usize), (3, 2, 2), (2, 3, 2), (4, 2, 1)] {
        let (hu, tu, du) = (heads as u64, t as u64, d as u64);
        for shared in [false, true] {
            let tag = format!("H={heads} T={t} d={d} shared={shared}");
            // Inhibitor and dot-product: disjoint subgraphs, exactly H×
            // the single-head closed forms at one head's level depth.
            let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, heads, shared);
            let p = mh.plan(t, d);
            assert_eq!(p.pbs_count(), hu * (2 * tu * tu * du + tu * tu + tu * du), "{tag}");
            assert_eq!(p.blind_rotation_count(), p.pbs_count());
            assert_eq!(p.levels(), 4, "{tag}: fused depth = one head's depth");
            assert_eq!(
                p.level_sizes(),
                vec![heads * t * t * d, heads * t * t, heads * t * t * d, heads * t * d],
                "{tag}: per-level jobs are H× one head's"
            );
            assert_eq!(p.n_inputs(), mh.n_plan_inputs(t, d));
            assert_eq!(p.n_outputs(), heads * t * d);
            let dot = MultiHeadFhe::new(Mechanism::DotProduct, d, heads, shared).plan(t, d);
            assert_eq!(
                dot.pbs_count(),
                hu * (4 * tu * tu * du + 3 * tu * tu + tu + tu * du),
                "{tag} dotprod"
            );
            assert_eq!(dot.levels(), 6);
            // Signed: verbatim emission is H× regardless of layout; the
            // rewrite outcomes differ *only* through cross-head sharing.
            let mh = MultiHeadFhe::new(Mechanism::InhibitorSigned, d, heads, shared);
            let raw = mh.plan(t, d);
            assert_eq!(
                raw.pbs_count(),
                hu * (5 * tu * tu * du + tu * tu + tu * du),
                "{tag} signed verbatim"
            );
            let (cse, _) = PlanRewriter::new(RewriteConfig::cse_only()).rewrite(mh.plan(t, d));
            let want_cse = if shared {
                3 * hu * tu * tu * du + hu * tu * tu + hu * tu * du + 2 * tu * du
            } else {
                hu * (3 * tu * tu * du + tu * tu + 3 * tu * du)
            };
            assert_eq!(cse.pbs_count(), want_cse, "{tag} signed CSE'd");
            let (packed, stats) = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 2 })
                .rewrite(mh.plan(t, d));
            assert_eq!(packed.pbs_count(), want_cse, "packing keeps LUT evaluations");
            let want_rot = if shared {
                3 * hu * tu * tu * du + hu * tu * tu + hu * tu * du + tu * du
            } else {
                hu * (3 * tu * tu * du + tu * tu + 2 * tu * du)
            };
            assert_eq!(packed.blind_rotation_count(), want_rot, "{tag} signed packed");
            // Shared KV: one split-pair group per value for the WHOLE
            // block; per-head KV: one per value per head.
            let want_groups = if shared { t * d } else { heads * t * d };
            assert_eq!(stats.multi_groups, want_groups, "{tag} groups");
            assert_eq!(packed.levels(), 4, "packing never crosses levels");
        }
    }
}

#[test]
fn fused_shared_kv_signed_plan_beats_h_separate_plans_on_rotations() {
    // The acceptance-bar pin: with any packing budget ≥ 2 (many_lut_log
    // ≥ 1), the fused H-head shared-KV signed plan needs STRICTLY fewer
    // blind rotations than H separately-rewritten single-head plans —
    // the first super-pairwise, cross-head saving the IR machinery
    // delivers end-to-end. The margin is exactly the (H−1)·T·d split
    // rotations the separate plans each repeat.
    let _g = lock();
    let rewriter = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 2 });
    for &(heads, t, d) in &[(2usize, 2usize, 2usize), (3, 2, 2), (4, 3, 2)] {
        let single = InhibitorSignedFhe::new(d, 1);
        let (single_rw, _) = rewriter.rewrite(single.plan(t, d));
        let h_separate_rot = heads as u64 * single_rw.blind_rotation_count();
        let h_separate_pbs = heads as u64 * single_rw.pbs_count();
        let mh = MultiHeadFhe::new(Mechanism::InhibitorSigned, d, heads, true);
        let (fused, _) = rewriter.rewrite(mh.plan(t, d));
        assert!(
            fused.blind_rotation_count() < h_separate_rot,
            "H={heads} T={t} d={d}: fused {} !< separate {}",
            fused.blind_rotation_count(),
            h_separate_rot
        );
        assert_eq!(
            h_separate_rot - fused.blind_rotation_count(),
            (heads as u64 - 1) * (t * d) as u64,
            "the rotation win is exactly the deduped split pairs"
        );
        // Cross-head CSE also cuts LUT evaluations themselves.
        assert!(fused.pbs_count() < h_separate_pbs, "H={heads}: cross-head CSE win");
        assert_eq!(h_separate_pbs - fused.pbs_count(), 2 * (heads as u64 - 1) * (t * d) as u64);
    }
}

#[test]
fn forward_does_not_clone_input_ciphertexts() {
    // By-ref execution regression: for circuits whose inputs feed only
    // free linear nodes (unsigned inhibitor, dot-product — single- and
    // multi-head alike), a forward pass performs ZERO CtInt clones:
    // inputs are borrowed, single-consumer PBS operands are moved into
    // their jobs, and outputs are moved out at finish. This holds for
    // the raw and the rewritten pipeline identically (the passes don't
    // touch these circuits), so the pin survives the FHE_NO_REWRITE CI
    // leg too.
    let _g = lock();
    let mut rng = Xoshiro256::new(0x31AD04);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (heads, t, d) = (2usize, 2usize, 2usize);
    let q = ITensor::random(&[t, heads * d], -2, 2, &mut rng);
    let k = ITensor::random(&[t, heads * d], -2, 2, &mut rng);
    let v = ITensor::random(&[t, heads * d], 0, 3, &mut rng);
    let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
    let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
    let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
    let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, heads, false);
    let single = inhibitor::fhe_circuits::InhibitorFhe::new(d, 1);
    let sq = CtMatrix::encrypt(&q.slice_cols(0, d), &ctx, &ck, &mut rng);
    let sk_ = CtMatrix::encrypt(&k.slice_cols(0, d), &ctx, &ck, &mut rng);
    let sv = CtMatrix::encrypt(&v.slice_cols(0, d), &ctx, &ck, &mut rng);
    // Warm both plan caches so the measurement is the steady-state path.
    let _ = mh.forward(&ctx, &cq, &ckk, &cv);
    let _ = single.forward(&ctx, &sq, &sk_, &sv);
    let before = ct_clone_count();
    let out_mh = mh.forward(&ctx, &cq, &ckk, &cv);
    assert_eq!(
        ct_clone_count() - before,
        0,
        "multi-head inhibitor forward must not clone any ciphertext"
    );
    let before = ct_clone_count();
    let out_single = single.forward(&ctx, &sq, &sk_, &sv);
    assert_eq!(
        ct_clone_count() - before,
        0,
        "single-head inhibitor forward must not clone any ciphertext"
    );
    // Dot-product too: its inputs also feed only linear (add/sub) nodes
    // and every PBS operand is single-consumer. The clone counter is
    // value-independent, so reusing the 5-bit keyset is fine even where
    // the baseline's intermediates would wrap at this width.
    let dot = inhibitor::fhe_circuits::DotProductFhe::new(d, 2);
    let _ = dot.forward(&ctx, &sq, &sk_, &sv); // warm the plan cache
    let before = ct_clone_count();
    let out_dot = dot.forward(&ctx, &sq, &sk_, &sv);
    assert_eq!(
        ct_clone_count() - before,
        0,
        "dot-product forward must not clone any ciphertext"
    );
    // The runs above were real forwards (sanity, not vacuous).
    assert_eq!(out_mh.data.len(), heads * t * d);
    assert_eq!(out_single.data.len(), t * d);
    assert_eq!(out_dot.data.len(), t * d);
}

#[test]
fn multihead_engine_serves_coscheduled_requests_through_fusion() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x31AD05);
    let (heads, t, d) = (2usize, 2usize, 2usize);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    let n_req = 2usize;
    coord
        .add_fhe_multihead_engine(
            session,
            "inhibitor",
            t,
            d,
            heads,
            false,
            BatchPolicy { max_batch: n_req, max_wait: Duration::from_secs(2), queue_cap: 64 },
        )
        .unwrap();
    let sess = coord.keymgr.session(session).unwrap();
    let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, heads, false);
    // The engine resolves the same cached-plan construction on its own
    // worker; PBS is deterministic, so solo executions of this plan are
    // the exact reference.
    let plan = mh.plan_for(&sess.ctx, t, d);
    let mut tensors = Vec::with_capacity(n_req);
    let mut bundles: Vec<Vec<CtInt>> = Vec::with_capacity(n_req);
    for _ in 0..n_req {
        let q = ITensor::random(&[t, heads * d], -2, 2, &mut rng);
        let k = ITensor::random(&[t, heads * d], -2, 2, &mut rng);
        let v = ITensor::random(&[t, heads * d], 0, 3, &mut rng);
        let cq = CtMatrix::encrypt(&q, &sess.ctx, &ck, &mut rng);
        let ckk = CtMatrix::encrypt(&k, &sess.ctx, &ck, &mut rng);
        let cv = CtMatrix::encrypt(&v, &sess.ctx, &ck, &mut rng);
        // Wire layout = plan-input layout, defined once by input_refs.
        bundles.push(mh.input_refs(&cq, &ckk, &cv).into_iter().cloned().collect());
        tensors.push((q, k, v));
    }
    let solo: Vec<Vec<CtInt>> = bundles.iter().map(|b| plan.execute(&sess.ctx, b)).collect();
    let path = EnginePath::Encrypted { session, mechanism: mh.engine_mechanism() };
    let rxs: Vec<_> = bundles
        .iter()
        .map(|b| {
            let blob = sess.register(b.clone());
            coord.submit(path.clone(), Payload::CiphertextRef(blob)).unwrap()
        })
        .collect();
    let resps: Vec<_> =
        rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(300)).unwrap()).collect();
    for resp in &resps {
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    // Both requests rode ONE fused batch: one fused submission per plan
    // level (H× the single-head jobs inside each).
    let m = coord.metrics();
    assert_eq!(
        m.fused_levels.load(std::sync::atomic::Ordering::Relaxed),
        plan.levels() as u64,
        "co-scheduled multi-head requests must fuse at level granularity"
    );
    for (r, resp) in resps.iter().enumerate() {
        let cts = sess.take(resp.result_blob.expect("typed result reference")).unwrap();
        assert_eq!(cts.len(), heads * t * d);
        for (i, (got, want)) in cts.iter().zip(&solo[r]).enumerate() {
            assert_eq!(got.ct, want.ct, "request {r} output {i}: fused == solo");
        }
        let (q, k, v) = &tensors[r];
        let mirror = mh.mirror(q, k, v, sess.ctx.enc.min_signed(), sess.ctx.enc.max_signed());
        let got: Vec<i64> = cts.iter().map(|c| sess.ctx.decrypt(c, &ck)).collect();
        assert_eq!(got, mirror.data, "request {r}: plaintext multi-head reference");
    }
    assert_eq!(mh.plan_builds(), 1, "reference plan built once from the shared cache");
}

#[test]
fn multihead_plan_cache_builds_once_across_forwards_and_clones() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x31AD06);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (heads, t, d) = (2usize, 2usize, 2usize);
    let q = ITensor::random(&[t, heads * d], -2, 2, &mut rng);
    let k = ITensor::random(&[t, heads * d], -2, 2, &mut rng);
    let v = ITensor::random(&[t, heads * d], 0, 3, &mut rng);
    let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
    let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
    let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
    let mh = MultiHeadFhe::new(Mechanism::Inhibitor, d, heads, false);
    assert_eq!(mh.plan_builds(), 0);
    let first = mh.forward(&ctx, &cq, &ckk, &cv);
    let second = mh.forward(&ctx, &cq, &ckk, &cv);
    assert_eq!(mh.plan_builds(), 1, "repeated forwards reuse the cached fused plan");
    let clone = mh.clone();
    let third = clone.forward(&ctx, &cq, &ckk, &cv);
    assert_eq!(clone.plan_builds(), 1, "clones share the cache");
    for (a, b) in first.data.iter().zip(second.data.iter()) {
        assert_eq!(a.ct, b.ct, "cached plan must not change results");
    }
    for (a, b) in first.data.iter().zip(third.data.iter()) {
        assert_eq!(a.ct, b.ct);
    }
}
