//! E2E encrypted path: full attention circuits under real TFHE equal
//! their plaintext mirrors; the quantized engine and the encrypted engine
//! agree on the same integer inputs; noise stays within budget across a
//! whole forward pass.

use inhibitor::fhe_circuits::{CtMatrix, DotProductFhe, InhibitorFhe};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::{bootstrap, ClientKey, FheContext, TfheParams};
use inhibitor::util::prng::Xoshiro256;
use std::sync::Mutex;

/// `PBS_COUNT` is process-global and this binary's tests run on parallel
/// threads; every test here bootstraps, so they serialize through this
/// lock to keep the count-based assertions exact.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn ctx_with_bits(bits: u32, seed: u64) -> (ClientKey, FheContext, Xoshiro256) {
    let mut rng = Xoshiro256::new(seed);
    let p = TfheParams::test_for_bits(bits);
    let ck = ClientKey::generate(p, &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    (ck, ctx, rng)
}

#[test]
fn encrypted_inhibitor_t4_matches_mirror() {
    let _guard = lock();
    let (ck, ctx, mut rng) = ctx_with_bits(5, 42);
    let (t, d) = (4usize, 2usize);
    let q = ITensor::random(&[t, d], -2, 2, &mut rng);
    let k = ITensor::random(&[t, d], -2, 2, &mut rng);
    let v = ITensor::random(&[t, d], 0, 3, &mut rng);
    let head = InhibitorFhe::new(d, 1);
    let h = head.forward(
        &ctx,
        &CtMatrix::encrypt(&q, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&k, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&v, &ctx, &ck, &mut rng),
    );
    assert_eq!(h.decrypt(&ctx, &ck), head.mirror(&q, &k, &v, ctx.enc.max_signed()));
}

#[test]
fn encrypted_vs_quantized_engine_consistency() {
    // The encrypted circuit and the plaintext quantized engine compute the
    // same integer function when fed the same codes (the FHE circuit's
    // clamps are the only divergence; inputs chosen to avoid them).
    let _guard = lock();
    let (ck, ctx, mut rng) = ctx_with_bits(6, 7);
    let (t, d) = (2usize, 2usize);
    let q = ITensor::from_vec(&[t, d], vec![1, 0, -1, 2]);
    let k = ITensor::from_vec(&[t, d], vec![0, 1, 2, -1]);
    let v = ITensor::from_vec(&[t, d], vec![2, 3, 1, 0]);
    let head = InhibitorFhe::new(d, 1);
    let enc_out = head
        .forward(
            &ctx,
            &CtMatrix::encrypt(&q, &ctx, &ck, &mut rng),
            &CtMatrix::encrypt(&k, &ctx, &ck, &mut rng),
            &CtMatrix::encrypt(&v, &ctx, &ck, &mut rng),
        )
        .decrypt(&ctx, &ck);
    let mirror = head.mirror(&q, &k, &v, ctx.enc.max_signed());
    assert_eq!(enc_out, mirror);
    // And the mirror itself equals the naive plaintext inhibition (γ=√2,
    // α=1 at the integer scale) computed via the attention module.
    let z = inhibitor::attention::inhibitor::inhibitor_scores(
        &q,
        &k,
        inhibitor::quant::FixedMult::from_f64(1.0 / (2f64).sqrt()),
        1,
    );
    let naive = inhibitor::attention::inhibitor::inhibit_naive(&z, &v);
    assert_eq!(mirror, naive, "FHE mirror vs attention-module integer math");
}

#[test]
fn encrypted_dotprod_runs_and_matches_mirror_t2() {
    let _guard = lock();
    let (ck, ctx, mut rng) = ctx_with_bits(6, 1234);
    let (t, d) = (2usize, 2usize);
    let q = ITensor::from_vec(&[t, d], vec![1, -1, 0, 2]);
    let k = ITensor::from_vec(&[t, d], vec![1, 1, -1, 1]);
    let v = ITensor::from_vec(&[t, d], vec![2, 1, -1, 3]);
    let head = DotProductFhe::new(d, 2);
    bootstrap::reset_pbs_count();
    let h = head.forward(
        &ctx,
        &CtMatrix::encrypt(&q, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&k, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&v, &ctx, &ck, &mut rng),
    );
    let pbs_dot = bootstrap::pbs_count();
    assert_eq!(
        h.decrypt(&ctx, &ck),
        head.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed())
    );
    // Paper claim: dot-product needs about twice the PBS of the inhibitor.
    bootstrap::reset_pbs_count();
    let _ = InhibitorFhe::new(d, 1).forward(
        &ctx,
        &CtMatrix::encrypt(&q, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&k, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&v.abs(), &ctx, &ck, &mut rng),
    );
    let pbs_inh = bootstrap::pbs_count();
    let ratio = pbs_dot as f64 / pbs_inh as f64;
    assert!(ratio > 1.4, "PBS ratio dot/inh = {ratio} ({pbs_dot}/{pbs_inh})");
}

#[test]
fn noise_survives_a_long_linear_chain_between_bootstraps() {
    // Sum 8 fresh ciphertexts (the longest chain the attention circuits
    // use at T=8), bootstrap, decode — must be exact.
    let _guard = lock();
    let (ck, ctx, mut rng) = ctx_with_bits(5, 55);
    let ones: Vec<_> = (0..8).map(|_| ctx.encrypt(1, &ck, &mut rng)).collect();
    let sum = ctx.sum(&ones);
    let refreshed = ctx.relu(&sum);
    assert_eq!(ctx.decrypt(&refreshed, &ck), 8);
}
