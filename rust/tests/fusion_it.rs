//! Cross-request PBS batch fusion: co-scheduled encrypted requests must
//! (a) execute as fused per-level `pbs_batch` submissions whose sizes are
//! the *sums* of the per-request plan level sizes, (b) cost exactly the
//! sum of the per-request plan PBS counts (fusion changes scheduling,
//! never accounting), and (c) return bit-identical results to
//! single-request execution.

use inhibitor::coordinator::{BatchPolicy, Coordinator, EnginePath, Payload, RoutePolicy};
use inhibitor::fhe_circuits::InhibitorFhe;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{bootstrap, ClientKey, FheContext, TfheParams};
use inhibitor::util::prng::{Rng64, Xoshiro256};
use std::sync::Mutex;
use std::time::Duration;

/// `PBS_COUNT` is process-global and tests in this binary run on parallel
/// threads; count-sensitive tests serialize through this lock.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn encrypt_qkv(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    t: usize,
    d: usize,
) -> Vec<CtInt> {
    (0..3 * t * d)
        .map(|i| {
            let v = if i < 2 * t * d {
                rng.next_range_i64(-2, 2) // q, k codes
            } else {
                rng.next_range_i64(0, 3) // v codes
            };
            ctx.encrypt(v, ck, rng)
        })
        .collect()
}

#[test]
fn coscheduled_requests_fuse_and_match_single_request_execution() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xFA5E);
    let (t, d) = (2usize, 2usize);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let head = InhibitorFhe::new(d, 1);
    let plan = head.plan(t, d);

    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    // max_batch = n_req with a generous wait: both submissions land in
    // one batch, which the engine executes as one fused run.
    let n_req = 2usize;
    coord
        .add_fhe_engine(
            session,
            "inhibitor",
            t,
            d,
            BatchPolicy { max_batch: n_req, max_wait: Duration::from_secs(2), queue_cap: 64 },
        )
        .unwrap();
    let sess = coord.keymgr.session(session).unwrap();

    // Per-request bundles + solo reference executions on the same
    // context (PBS is deterministic, so solo vs fused is exact).
    let bundles: Vec<Vec<CtInt>> =
        (0..n_req).map(|_| encrypt_qkv(&sess.ctx, &ck, &mut rng, t, d)).collect();
    let solo: Vec<Vec<CtInt>> =
        bundles.iter().map(|inputs| plan.execute(&sess.ctx, inputs)).collect();

    let before = bootstrap::pbs_count();
    let rxs: Vec<_> = bundles
        .iter()
        .map(|inputs| {
            let blob = sess.register(inputs.clone());
            coord
                .submit(
                    EnginePath::Encrypted { session, mechanism: "inhibitor".into() },
                    Payload::CiphertextRef(blob),
                )
                .unwrap()
        })
        .collect();
    let resps: Vec<_> =
        rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(300)).unwrap()).collect();
    for resp in &resps {
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    // Accounting: the fused batch costs exactly Σ per-request plan counts.
    assert_eq!(
        bootstrap::pbs_count() - before,
        n_req as u64 * plan.pbs_count(),
        "fusion must not change the PBS count"
    );
    // The engine recorded one fused submission per plan level, each the
    // size of the summed per-request level (worker-pool fill).
    let m = coord.metrics();
    assert_eq!(
        m.fused_levels.load(std::sync::atomic::Ordering::Relaxed),
        plan.levels() as u64,
        "both requests must ride one fused batch"
    );
    assert_eq!(
        m.fused_pbs.load(std::sync::atomic::Ordering::Relaxed),
        n_req as u64 * plan.pbs_count()
    );
    let expect_mean = (n_req as u64 * plan.pbs_count()) as f64 / plan.levels() as f64;
    assert!((m.mean_fused_level_size() - expect_mean).abs() < 1e-9);
    // Results: bit-identical to the solo executions.
    for (r, resp) in resps.iter().enumerate() {
        let cts = sess.take(resp.result_blob.expect("typed result reference")).unwrap();
        assert_eq!(cts.len(), t * d);
        for (i, (got, want)) in cts.iter().zip(&solo[r]).enumerate() {
            assert_eq!(got.ct, want.ct, "request {r} output {i}");
        }
        // And equal to the plaintext mirror.
        let vals: Vec<i64> = bundles[r].iter().map(|c| sess.ctx.decrypt(c, &ck)).collect();
        let q = inhibitor::tensor::ITensor::from_vec(&[t, d], vals[0..t * d].to_vec());
        let k = inhibitor::tensor::ITensor::from_vec(&[t, d], vals[t * d..2 * t * d].to_vec());
        let v = inhibitor::tensor::ITensor::from_vec(&[t, d], vals[2 * t * d..].to_vec());
        let mirror = head.mirror(&q, &k, &v, sess.ctx.enc.max_signed());
        let got: Vec<i64> = cts.iter().map(|c| sess.ctx.decrypt(c, &ck)).collect();
        assert_eq!(got, mirror.data, "request {r} mirror");
    }
}

#[test]
fn lone_request_still_served_through_fused_path() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x10E);
    let (t, d) = (2usize, 2usize);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let head = InhibitorFhe::new(d, 1);
    let plan = head.plan(t, d);
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    coord
        .add_fhe_engine(
            session,
            "inhibitor",
            t,
            d,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), queue_cap: 64 },
        )
        .unwrap();
    let sess = coord.keymgr.session(session).unwrap();
    let inputs = encrypt_qkv(&sess.ctx, &ck, &mut rng, t, d);
    let want = plan.execute(&sess.ctx, &inputs);
    let blob = sess.register(inputs);
    let resp = coord
        .infer_blocking(
            EnginePath::Encrypted { session, mechanism: "inhibitor".into() },
            Payload::CiphertextRef(blob),
            Duration::from_secs(300),
        )
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let cts = sess.take(resp.result_blob.expect("typed result reference")).unwrap();
    for (got, want) in cts.iter().zip(&want) {
        assert_eq!(got.ct, want.ct, "batch-of-one must equal solo execution");
    }
}
