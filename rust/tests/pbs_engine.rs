//! Batched parallel PBS engine tests: `pbs_many` must be value- and
//! count-equivalent to sequential `pbs` at every worker count, cached
//! `PreparedLut` accumulators must be bit-identical to the on-the-fly
//! path, and the level-synchronous circuits must stay exact under
//! threading.

use inhibitor::fhe_circuits::{CtMatrix, InhibitorFhe};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::{bootstrap, ClientKey, Encoder, FheContext, Lut, TfheParams};
use inhibitor::util::prng::{Rng64, Xoshiro256};
use std::sync::Mutex;

/// `PBS_COUNT` is process-global and the tests in this binary run on
/// parallel threads; count-sensitive tests serialize through this lock
/// (every test here that bootstraps takes it).
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn pbs_many_matches_sequential_and_count_is_thread_invariant() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xBA7C);
    let ck = ClientKey::generate(TfheParams::test_for_bits(4), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    // Property: random batches, random values, every worker count.
    for case in 0..6u64 {
        let batch = 1 + (case as usize) * 3; // 1, 4, 7, 10, 13, 16
        let vals: Vec<i64> = (0..batch).map(|_| rng.next_range_i64(-8, 7)).collect();
        let cts: Vec<_> = vals.iter().map(|&v| ctx.encrypt(v, &ck, &mut rng)).collect();
        let lut = ctx.prepared_fn(|v| (v / 2).max(-3));
        // Sequential reference (1 PBS per element, same prepared table).
        ctx.set_threads(1);
        let reference = ctx.pbs_many(&cts, &lut);
        for threads in [2usize, 3, 4] {
            ctx.set_threads(threads);
            let before = bootstrap::pbs_count();
            let batched = ctx.pbs_many(&cts, &lut);
            assert_eq!(
                bootstrap::pbs_count() - before,
                batch as u64,
                "PBS_COUNT must be exact at threads={threads} (case {case})"
            );
            for (i, (seq, par)) in reference.iter().zip(batched.iter()).enumerate() {
                assert_eq!(
                    seq.ct, par.ct,
                    "bit-identical ciphertexts, case {case} threads={threads} i={i}"
                );
            }
            for (i, out) in batched.iter().enumerate() {
                assert_eq!(
                    ctx.decrypt(out, &ck),
                    (vals[i] / 2).max(-3),
                    "decrypt, case {case} threads={threads} i={i}"
                );
            }
        }
    }
}

#[test]
fn cached_prepared_lut_is_bit_identical_to_on_the_fly_pbs() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xCAC4E);
    let params = TfheParams::test_small();
    let ck = ClientKey::generate(params, &mut rng);
    let sk = ck.server_key(&mut rng);
    let enc = Encoder::new(params);
    let space = params.message_space();
    let lut = Lut::from_fn(&params, |m| (5 * m + 3) % space);
    let prepared = sk.prepare_lut(&lut);
    for m in 0..space {
        let ct = enc.encrypt_raw(m, &ck, &mut rng);
        let on_the_fly = sk.pbs(&ct, &lut);
        let cached = sk.pbs_prepared(&ct, &prepared);
        assert_eq!(on_the_fly, cached, "m={m}");
        assert_eq!(enc.decrypt_raw(&cached, &ck), (5 * m + 3) % space, "m={m}");
    }
}

#[test]
fn inhibitor_forward_is_exact_and_count_stable_across_thread_counts() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x1B17);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (t, d) = (2usize, 2usize);
    let q = ITensor::from_vec(&[t, d], vec![1, -2, 0, 2]);
    let k = ITensor::from_vec(&[t, d], vec![1, -1, -2, 0]);
    let v = ITensor::from_vec(&[t, d], vec![3, 1, 2, 0]);
    let head = InhibitorFhe::new(d, 1);
    let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
    let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
    let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
    let want = head.mirror(&q, &k, &v, ctx.enc.max_signed());
    let expect_pbs = (2 * t * t * d + t * t + t * d) as u64;
    let mut first: Option<Vec<_>> = None;
    for threads in [1usize, 2, 4] {
        ctx.set_threads(threads);
        let before = bootstrap::pbs_count();
        let h = head.forward(&ctx, &cq, &ckk, &cv);
        assert_eq!(
            bootstrap::pbs_count() - before,
            expect_pbs,
            "per-head PBS count at threads={threads}"
        );
        assert_eq!(h.decrypt(&ctx, &ck), want, "mirror equality at threads={threads}");
        let cts: Vec<_> = h.data.iter().map(|c| c.ct.clone()).collect();
        match &first {
            None => first = Some(cts),
            Some(f) => {
                assert_eq!(f, &cts, "outputs must be bit-identical across thread counts")
            }
        }
    }
}
