//! Differential test harness for the **incremental decode** subsystem
//! (`fhe_circuits::DecodeFhe` + the coordinator's session ciphertext
//! state, PR 7).
//!
//! * **Stream ≡ one-shot**: over mechanism × heads ∈ {1, 2} × layers ∈
//!   {1, 2} (plus a shared-KV point), a stream of single-token decode
//!   steps must be **bit-identical** to the one-shot causal prefill
//!   forward at EVERY prefix length — output rows and the entire
//!   encrypted KV-cache bundle — and decode to the streaming plaintext
//!   mirror. Steps run alternating 1 and 4 PBS worker threads, and
//!   `decode.step` resolves plans through the `FHE_NO_REWRITE`-honoring
//!   cache, so the CI no-rewrite and thread legs drive both pipelines
//!   through here. Every grid point additionally runs under **both**
//!   PBS dispatch modes — the wavefront ready-set stepper and the
//!   legacy level barrier — on identical inputs, pinned bit-identical
//!   with the same counter deltas (PR 8).
//! * **Closed forms**: every step's `PBS_COUNT`/`BLIND_ROTATION_COUNT`
//!   delta equals the executed plan's own prediction, and (rewrites on)
//!   the plan's counts equal `optimizer::profile_step` — whose
//!   per-prefix growth is pinned **constant** (strictly O(t·d): no T²
//!   term hides in a second difference).
//! * **Serving**: `Coordinator::add_fhe_decode_engine` streams through
//!   the session store — prefill deposits the cache bundle, steps
//!   consume and replace it by move, results come back as typed
//!   `result_blob` references bit-identical to solo execution; gauges
//!   (`decode_steps`, `cache_blobs_live`, `cache_bytes`), the explicit
//!   `release_cache`, the per-session cap with its typed
//!   `cache_overflow`, and the restore-on-failure contract are pinned.
//!
//! Counters are process-global and libtest runs tests on parallel
//! threads, so every test serializes through one lock.

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::storage::DEFAULT_STORAGE_BUDGET;
use inhibitor::coordinator::{
    BatchPolicy, Coordinator, DiskSink, EnginePath, InferRequest, MemorySink, Payload, RoutePolicy,
    Session,
};
use inhibitor::fhe_circuits::{CtMatrix, DecodeFhe, DecodeMirror, ModelFhe};
use inhibitor::optimizer::profile_step;
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    bootstrap, rewrites_disabled, set_wavefront_dispatch, ClientKey, FaultPlan, FheContext,
    TfheParams,
};
use inhibitor::util::prng::Xoshiro256;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pins the PBS dispatch mode for a scope and restores the
/// environment-driven default on drop (panic-safe — a failing assert
/// must not leak a forced mode into sibling tests).
struct WavefrontGuard;

impl WavefrontGuard {
    fn set(mode: bool) -> Self {
        set_wavefront_dispatch(Some(mode));
        WavefrontGuard
    }
}

impl Drop for WavefrontGuard {
    fn drop(&mut self) {
        set_wavefront_dispatch(None);
    }
}

/// One grid point: stream T = 3 tokens (prefill 1, then 2 steps) and pin
/// the stream against the one-shot causal forward at every prefix
/// length, bit for bit, with per-step counter deltas matching the
/// executed plan and (rewrites on) the `profile_step` closed forms.
#[allow(clippy::too_many_arguments)]
fn check_stream(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    mech: Mechanism,
    heads: usize,
    layers: usize,
    d: usize,
    shared_kv: bool,
) -> Vec<CtInt> {
    let tag = format!("{mech:?} H={heads} L={layers} d={d} shared={shared_kv}");
    let dm = heads * d;
    let t_total = 3usize;
    let model = ModelFhe::demo(mech, dm, heads, layers, shared_kv, dm, 0xDEC0DE + layers as u64);
    let decode = DecodeFhe::new(model);
    let x = ITensor::random(&[t_total, dm], -1, 1, rng);
    let cx = CtMatrix::encrypt(&x, ctx, ck, rng);
    let mut mirror = DecodeMirror::new(&decode.model, ctx.enc.min_signed(), ctx.enc.max_signed());
    let m_out = mirror.prefill(&x);
    // One-shot causal references at EVERY prefix length, on the same
    // input ciphertexts (PBS is deterministic, so bit-identity is the
    // bar, not just equal decodes).
    let one_shot: Vec<(Vec<CtInt>, Vec<CtInt>)> = (1..=t_total)
        .map(|p| {
            let xp = CtMatrix { rows: p, cols: dm, data: cx.data[..p * dm].to_vec() };
            let (out, cache) = decode.prefill(ctx, &xp);
            (out.data, cache)
        })
        .collect();
    // The streamed path: prefill the first token, then one step per
    // remaining token, alternating the PBS worker count so both thread
    // budgets drive the same bit-identical recurrence.
    let x0 = CtMatrix { rows: 1, cols: dm, data: cx.data[..dm].to_vec() };
    let (out0, mut cache) = decode.prefill(ctx, &x0);
    let mut stream_out: Vec<CtInt> = out0.data;
    for t in 1..t_total {
        ctx.set_threads(if t % 2 == 1 { 1 } else { 4 });
        let row = &cx.data[t * dm..(t + 1) * dm];
        let plan = decode.step_plan_for(ctx, t);
        let before_pbs = bootstrap::pbs_count();
        let before_rot = bootstrap::blind_rotation_count();
        let (out_row, next) = decode.step(ctx, row, cache);
        assert_eq!(
            bootstrap::pbs_count() - before_pbs,
            plan.pbs_count(),
            "{tag} step t={t}: PBS delta"
        );
        assert_eq!(
            bootstrap::blind_rotation_count() - before_rot,
            plan.blind_rotation_count(),
            "{tag} step t={t}: rotation delta"
        );
        if !rewrites_disabled() {
            let prof = profile_step(mech, t, dm, heads, layers, dm, shared_kv, ctx.max_multi_lut());
            assert_eq!(plan.pbs_count(), prof.pbs_count, "{tag} t={t}: closed-form LUT evals");
            assert_eq!(
                plan.blind_rotation_count(),
                prof.blind_rotations,
                "{tag} t={t}: closed-form rotations"
            );
            assert_eq!(plan.levels() as u64, prof.levels, "{tag} t={t}: closed-form levels");
        }
        cache = next;
        // The streamed cache bundle is the one-shot bundle, bit for bit.
        let os_cache = &one_shot[t].1;
        assert_eq!(cache.len(), os_cache.len(), "{tag} t={t}: cache length");
        for (i, (a, b)) in cache.iter().zip(os_cache).enumerate() {
            assert_eq!(a.ct, b.ct, "{tag} t={t}: cache ct {i} streamed == one-shot");
        }
        // The step's output row is the one-shot grid's last row.
        let os_out = &one_shot[t].0;
        for (i, (a, b)) in out_row.iter().zip(&os_out[t * dm..]).enumerate() {
            assert_eq!(a.ct, b.ct, "{tag} t={t}: output {i} streamed == one-shot");
        }
        stream_out.extend(out_row);
    }
    ctx.set_threads(1);
    // The whole streamed output grid is the full one-shot forward …
    let full = &one_shot[t_total - 1].0;
    assert_eq!(stream_out.len(), full.len(), "{tag}: stream covers the grid");
    for (i, (a, b)) in stream_out.iter().zip(full).enumerate() {
        assert_eq!(a.ct, b.ct, "{tag}: grid ct {i} streamed == one-shot");
    }
    // … and decodes to the streaming plaintext mirror.
    let got: Vec<i64> = stream_out.iter().map(|c| ctx.decrypt(c, ck)).collect();
    assert_eq!(got, m_out.data, "{tag}: plaintext mirror");
    stream_out
}

/// Run one grid point under wavefront dispatch AND the legacy level
/// barrier, on identical inputs (the PRNG is forked so both runs derive
/// the same plaintexts and encryption randomness), and pin the two
/// streamed output grids **bit-identical**. Every in-stream assertion —
/// counter deltas vs the executed plan, closed forms, cache identity —
/// runs in both modes.
#[allow(clippy::too_many_arguments)]
fn check_stream_both_dispatch_modes(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    mech: Mechanism,
    heads: usize,
    layers: usize,
    d: usize,
    shared_kv: bool,
) {
    let mut rng_barrier = rng.clone();
    let wave = {
        let _m = WavefrontGuard::set(true);
        check_stream(ctx, ck, rng, mech, heads, layers, d, shared_kv)
    };
    let barrier = {
        let _m = WavefrontGuard::set(false);
        check_stream(ctx, ck, &mut rng_barrier, mech, heads, layers, d, shared_kv)
    };
    let tag = format!("{mech:?} H={heads} L={layers} d={d} shared={shared_kv}");
    assert_eq!(wave.len(), barrier.len(), "{tag}: grid sizes across dispatch modes");
    for (i, (a, b)) in wave.iter().zip(&barrier).enumerate() {
        assert_eq!(a.ct, b.ct, "{tag}: grid ct {i} wavefront == barrier");
    }
}

#[test]
fn decode_inhibitor_stream_equals_one_shot_at_every_prefix() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xDEC071);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    for &(heads, layers, d, shared) in &[
        (1usize, 1usize, 2usize, false),
        (2, 1, 1, false),
        (1, 2, 2, false),
        (2, 2, 1, false),
        (2, 1, 2, true),
    ] {
        check_stream_both_dispatch_modes(
            &ctx,
            &ck,
            &mut rng,
            Mechanism::Inhibitor,
            heads,
            layers,
            d,
            shared,
        );
    }
}

#[test]
fn decode_signed_inhibitor_stream_equals_one_shot_at_every_prefix() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xDEC072);
    // Packing-capable keyset: the new-token split pairs (and, stacked,
    // the boundary trios) pack — profile_step's saved-rotation terms are
    // live, not zero.
    let ck = ClientKey::generate(TfheParams::test_multi_lut(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    assert_eq!(ctx.max_multi_lut(), 2);
    for &(heads, layers, d, shared) in &[
        (1usize, 1usize, 2usize, false),
        (1, 2, 2, false),
        (2, 1, 1, false),
        (2, 2, 1, false),
        (2, 1, 2, true),
    ] {
        check_stream_both_dispatch_modes(
            &ctx,
            &ck,
            &mut rng,
            Mechanism::InhibitorSigned,
            heads,
            layers,
            d,
            shared,
        );
    }
}

#[test]
fn decode_dotprod_stream_equals_one_shot_at_every_prefix() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xDEC073);
    let ck = ClientKey::generate(TfheParams::test_for_bits(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    for &(heads, layers, d, shared) in &[
        (1usize, 1usize, 2usize, false),
        (1, 2, 2, false),
        (2, 1, 1, false),
        (2, 2, 1, false),
        (2, 1, 2, true),
    ] {
        check_stream_both_dispatch_modes(
            &ctx,
            &ck,
            &mut rng,
            Mechanism::DotProduct,
            heads,
            layers,
            d,
            shared,
        );
    }
}

#[test]
fn step_cost_growth_is_constant_per_position_no_t_squared() {
    // Pure plan analysis (no crypto): the per-step LUT count's FIRST
    // difference over the prefix length is a constant, so the second
    // difference is zero — per-step work is strictly O(t·d), never
    // O(t²). Pinned on the built plans themselves, not just the closed
    // forms, for every mechanism.
    let _g = lock();
    for mech in [Mechanism::Inhibitor, Mechanism::InhibitorSigned, Mechanism::DotProduct] {
        let model = ModelFhe::demo(mech, 2, 1, 1, false, 2, 0xDEC074);
        let decode = DecodeFhe::new(model);
        let pbs: Vec<u64> = (0..6).map(|t| decode.step_plan(t).pbs_count()).collect();
        let slopes: Vec<u64> = pbs.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(
            slopes.windows(2).all(|w| w[0] == w[1]),
            "{mech:?}: per-step LUT growth must be constant per position, got {pbs:?}"
        );
    }
}

#[test]
fn decode_engine_streams_through_the_session_store_bit_identically() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xDEC075);
    let (heads, layers, d) = (1usize, 2usize, 2usize);
    let dm = heads * d;
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    let model = ModelFhe::demo(Mechanism::Inhibitor, dm, heads, layers, false, dm, 0xDEC0);
    // Plan construction and PBS are both deterministic, so this solo
    // DecodeFhe executes the exact circuits the engine serves and solo
    // runs are a bit-identical reference.
    let decode = DecodeFhe::new(model.clone());
    coord.add_fhe_decode_engine(session, model, BatchPolicy::default()).unwrap();
    let sess = coord.keymgr.session(session).unwrap();
    let t_total = 3usize;
    let x = ITensor::random(&[t_total, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &sess.ctx, &ck, &mut rng);
    // In-process reference stream (PBS deterministic → bit-identity).
    let x0 = CtMatrix { rows: 1, cols: dm, data: cx.data[..dm].to_vec() };
    let (ref_out0, mut ref_cache) = decode.prefill(&sess.ctx, &x0);
    let path = EnginePath::Encrypted { session, mechanism: decode.engine_mechanism() };
    let stream_id = 77u64;
    let m = coord.metrics();
    // Prefill request opens the stream and deposits the cache bundle.
    let blob = sess.register(cx.data[..dm].to_vec());
    let req = InferRequest::new(0, path.clone(), Payload::CiphertextRef(blob))
        .with_cache(None, Some(stream_id));
    let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    assert!(resp.error.is_none(), "prefill: {:?}", resp.error);
    assert!(resp.output.is_empty(), "blob ids must not ride the f32 vector");
    let out = sess.take(resp.result_blob.expect("typed result reference")).unwrap();
    assert_eq!(out.len(), dm);
    for (i, (a, b)) in out.iter().zip(&ref_out0.data).enumerate() {
        assert_eq!(a.ct, b.ct, "prefill output {i}: served == solo");
    }
    assert_eq!(m.cache_blobs_live.load(Ordering::Relaxed), 1, "prefill deposited one bundle");
    assert!(m.cache_bytes.load(Ordering::Relaxed) > 0, "live bundle has bytes");
    assert_eq!(m.decode_steps.load(Ordering::Relaxed), 0, "a prefill is not a step");
    // Stream the remaining tokens as single-row step requests.
    for t in 1..t_total {
        let row = cx.data[t * dm..(t + 1) * dm].to_vec();
        let (ref_row, next) = decode.step(&sess.ctx, &row, ref_cache);
        ref_cache = next;
        let blob = sess.register(row);
        let req = InferRequest::new(0, path.clone(), Payload::CiphertextRef(blob))
            .with_cache(Some(stream_id), None);
        let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
        assert!(resp.error.is_none(), "step t={t}: {:?}", resp.error);
        let out = sess.take(resp.result_blob.expect("typed result reference")).unwrap();
        for (i, (a, b)) in out.iter().zip(&ref_row).enumerate() {
            assert_eq!(a.ct, b.ct, "step t={t} output {i}: served == solo");
        }
    }
    assert_eq!(m.decode_steps.load(Ordering::Relaxed), (t_total - 1) as u64);
    // The stream's live bundle equals the reference cache bit for bit.
    let entry = coord.session_store().take(session, stream_id).expect("live bundle");
    assert_eq!(entry.cached_len, t_total);
    assert_eq!(entry.cts.len(), ref_cache.len());
    for (i, (a, b)) in entry.cts.iter().zip(&ref_cache).enumerate() {
        assert_eq!(a.ct, b.ct, "cache ct {i}: stored == reference");
    }
    coord.session_store().restore(session, stream_id, entry);
    // Explicit release drops it and the gauges read zero.
    assert!(coord.release_cache(session, stream_id));
    assert!(!coord.release_cache(session, stream_id), "release is not idempotent-true");
    assert_eq!(m.cache_blobs_live.load(Ordering::Relaxed), 0);
    assert_eq!(m.cache_bytes.load(Ordering::Relaxed), 0);
    // A step against the released stream fails typed and restores the
    // row bundle for a clean resubmit.
    let blob = sess.register(cx.data[..dm].to_vec());
    let req = InferRequest::new(0, path, Payload::CiphertextRef(blob))
        .with_cache(Some(stream_id), None);
    let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    assert_eq!(resp.error.as_ref().map(|e| e.code()), Some("key_missing"), "{:?}", resp.error);
    assert!(sess.take(blob).is_some(), "row bundle restored after the miss");
}

#[test]
fn cache_cap_overflow_is_typed_and_restores_the_pre_step_world_exactly() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xDEC076);
    let (heads, layers, d) = (1usize, 1usize, 2usize);
    let dm = heads * d;
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    let model = ModelFhe::demo(Mechanism::Inhibitor, dm, heads, layers, false, dm, 0xDEC1);
    let decode = DecodeFhe::new(model.clone());
    coord.add_fhe_decode_engine(session, model, BatchPolicy::default()).unwrap();
    let sess = coord.keymgr.session(session).unwrap();
    let x = ITensor::random(&[2, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &sess.ctx, &ck, &mut rng);
    let path = EnginePath::Encrypted { session, mechanism: decode.engine_mechanism() };
    // Reference stream for stream A, computed solo up front.
    let xa = CtMatrix { rows: 1, cols: dm, data: cx.data[..dm].to_vec() };
    let (_, ref_cache0) = decode.prefill(&sess.ctx, &xa);
    let step_row = cx.data[dm..2 * dm].to_vec();
    let (ref_row1, ref_cache1) =
        decode.step(&sess.ctx, &step_row, ref_cache0.iter().cloned().collect());
    // Open streams A and B, then clamp the cap below the live count.
    for (stream, lo) in [(1u64, 0usize), (2, dm)] {
        let blob = sess.register(cx.data[lo..lo + dm].to_vec());
        let req = InferRequest::new(0, path.clone(), Payload::CiphertextRef(blob))
            .with_cache(None, Some(stream));
        let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
        assert!(resp.error.is_none(), "prefill stream {stream}: {:?}", resp.error);
        sess.take(resp.result_blob.unwrap()).unwrap();
    }
    coord.session_store().set_cache_cap(1);
    // A prefill for a third stream overflows: typed error, grid restored.
    let blob = sess.register(cx.data[..dm].to_vec());
    let req = InferRequest::new(0, path.clone(), Payload::CiphertextRef(blob))
        .with_cache(None, Some(3));
    let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    assert_eq!(
        resp.error.as_ref().map(|e| e.code()),
        Some("cache_overflow"),
        "{:?}",
        resp.error
    );
    assert!(sess.take(blob).is_some(), "prefill grid restored after overflow");
    // A step on A forking its output to a NEW stream overflows at the
    // deposit; the pre-step world must come back exactly: the row bundle
    // AND stream A's cache, bit for bit.
    let blob = sess.register(step_row.clone());
    let req = InferRequest::new(0, path.clone(), Payload::CiphertextRef(blob))
        .with_cache(Some(1), Some(4));
    let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    assert_eq!(
        resp.error.as_ref().map(|e| e.code()),
        Some("cache_overflow"),
        "{:?}",
        resp.error
    );
    let row = sess.take(blob).expect("row bundle restored after overflow");
    for (i, (a, b)) in row.iter().zip(&step_row).enumerate() {
        assert_eq!(a.ct, b.ct, "restored row ct {i}");
    }
    let entry = coord.session_store().take(session, 1).expect("stream A still live");
    assert_eq!(entry.cached_len, 1);
    for (i, (a, b)) in entry.cts.iter().zip(&ref_cache0).enumerate() {
        assert_eq!(a.ct, b.ct, "restored cache ct {i} == pre-step bundle");
    }
    coord.session_store().restore(session, 1, entry);
    // Cap lifted: the exact resubmit replays the step bit-identically.
    coord.session_store().set_cache_cap(8);
    let blob = sess.register(row);
    let req = InferRequest::new(0, path, Payload::CiphertextRef(blob)).with_cache(Some(1), None);
    let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    assert!(resp.error.is_none(), "resubmit: {:?}", resp.error);
    let out = sess.take(resp.result_blob.unwrap()).unwrap();
    for (i, (a, b)) in out.iter().zip(&ref_row1).enumerate() {
        assert_eq!(a.ct, b.ct, "resubmitted step output {i}");
    }
    let entry = coord.session_store().take(session, 1).unwrap();
    assert_eq!(entry.cached_len, 2);
    for (i, (a, b)) in entry.cts.iter().zip(&ref_cache1).enumerate() {
        assert_eq!(a.ct, b.ct, "post-resubmit cache ct {i}");
    }
}

/// Serve one token through a coordinator's decode engine: register the
/// row, submit, and take the typed result bundle back out.
fn serve_token(
    coord: &Coordinator,
    sess: &Session,
    session: u64,
    mechanism: &str,
    row: Vec<CtInt>,
    stream: u64,
    prefill: bool,
) -> Vec<CtInt> {
    let path = EnginePath::Encrypted { session, mechanism: mechanism.to_string() };
    let blob = sess.register(row);
    let (take_from, deposit_to) =
        if prefill { (None, Some(stream)) } else { (Some(stream), None) };
    let req = InferRequest::new(0, path, Payload::CiphertextRef(blob))
        .with_cache(take_from, deposit_to);
    let resp = coord.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    assert!(resp.error.is_none(), "token (prefill={prefill}): {:?}", resp.error);
    sess.take(resp.result_blob.expect("typed result reference")).unwrap()
}

/// The storage-tier differential (PR 9): the same decode stream served
/// through a zero-budget coordinator — EVERY bundle (input blobs, result
/// blobs, the KV-cache) evicted to a [`DiskSink`] and rehydrated through
/// the word codec on take — must be **bit-identical** to the stream
/// served all-in-memory, including the replay after an injected PBS
/// worker panic mid-stream. The logical-byte gauges must agree between
/// the two runs, and `drop_session` must leave zero bundles, zero bytes,
/// and an empty sink behind.
#[test]
fn spilled_decode_stream_is_bit_identical_to_in_memory_and_survives_faults() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xDEC077);
    let (heads, layers, d) = (1usize, 1usize, 2usize);
    let dm = heads * d;
    let t_total = 3usize;
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    // Fork the PRNG so both coordinators hold bit-identical server keys:
    // PBS is deterministic, so every served ciphertext must then match
    // bit for bit between the in-memory and spill-everything runs.
    let mut rng_b = rng.clone();
    let ctx_a = FheContext::new(ck.server_key(&mut rng));
    let ctx_b = FheContext::new(ck.server_key(&mut rng_b));
    // A: all-in-memory with the default budget, pinned explicitly so the
    // CI tiny-budget env leg cannot turn this arm into a spill run too.
    let mut coord_a = Coordinator::with_storage(
        RoutePolicy::PreferQuant,
        Arc::new(MemorySink::new()),
        DEFAULT_STORAGE_BUDGET,
    );
    // B: budget 0 over a disk sink — every bundle spills immediately.
    let dir = std::env::temp_dir().join(format!("inhibitor-decode-spill-{}", std::process::id()));
    let sink = Arc::new(DiskSink::new(&dir).expect("disk sink"));
    let mut coord_b = Coordinator::with_storage(RoutePolicy::PreferQuant, sink, 0);

    let model = ModelFhe::demo(Mechanism::Inhibitor, dm, heads, layers, false, dm, 0xDEC2);
    let mech = DecodeFhe::new(model.clone()).engine_mechanism();
    let sid_a = coord_a.keymgr.create_session(ctx_a);
    let sid_b = coord_b.keymgr.create_session(ctx_b);
    coord_a.add_fhe_decode_engine(sid_a, model.clone(), BatchPolicy::default()).unwrap();
    coord_b.add_fhe_decode_engine(sid_b, model, BatchPolicy::default()).unwrap();
    let sess_a = coord_a.keymgr.session(sid_a).unwrap();
    let sess_b = coord_b.keymgr.session(sid_b).unwrap();

    let x = ITensor::random(&[t_total, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &sess_a.ctx, &ck, &mut rng);
    let stream = 9u64;

    // Prefill + first step through both coordinators, pinned identical.
    for (t, prefill) in [(0usize, true), (1, false)] {
        let row = cx.data[t * dm..(t + 1) * dm].to_vec();
        let out_a = serve_token(&coord_a, &sess_a, sid_a, &mech, row.clone(), stream, prefill);
        let out_b = serve_token(&coord_b, &sess_b, sid_b, &mech, row, stream, prefill);
        assert_eq!(out_a.len(), out_b.len(), "t={t}: output sizes");
        for (i, (a, b)) in out_a.iter().zip(&out_b).enumerate() {
            assert_eq!(a.ct, b.ct, "t={t} output {i}: spilled == in-memory");
        }
    }
    let sm_b = &coord_b.metrics().storage;
    assert!(sm_b.evictions.load(Ordering::Relaxed) > 0, "budget 0 must evict");
    assert!(sm_b.rehydrations.load(Ordering::Relaxed) > 0, "takes must rehydrate from the sink");
    assert!(sm_b.hit_rate() < 1.0, "every tier take on B touched the sink");
    assert_eq!(
        coord_a.metrics().storage.evictions.load(Ordering::Relaxed),
        0,
        "the in-memory arm must never spill"
    );
    // Logical-byte accounting: the gauges agree between the runs even
    // though B's bundles live encoded in the sink.
    assert_eq!(
        coord_a.metrics().cache_blobs_live.load(Ordering::Relaxed),
        coord_b.metrics().cache_blobs_live.load(Ordering::Relaxed),
        "live-bundle gauges agree across tiers"
    );
    let bytes_a = coord_a.metrics().cache_bytes.load(Ordering::Relaxed);
    assert!(bytes_a > 0);
    assert_eq!(
        bytes_a,
        coord_b.metrics().cache_bytes.load(Ordering::Relaxed),
        "spilled bundles are gauged at their decoded (logical) size"
    );

    // Inject a PBS worker panic into B's final step: the request fails
    // typed, the row bundle and the spilled cache come back intact, and
    // the disarmed replay is bit-identical to A's fault-free step.
    let row = cx.data[2 * dm..3 * dm].to_vec();
    let out_a = serve_token(&coord_a, &sess_a, sid_a, &mech, row.clone(), stream, false);
    let fault_spec = "panic@pbs:1";
    sess_b.ctx.set_fault_plan(Some(Arc::new(FaultPlan::parse(fault_spec).unwrap())));
    let path = EnginePath::Encrypted { session: sid_b, mechanism: mech.clone() };
    let blob = sess_b.register(row);
    let req = InferRequest::new(0, path, Payload::CiphertextRef(blob))
        .with_cache(Some(stream), None);
    let resp = coord_b.infer_request_blocking(req, Duration::from_secs(600)).unwrap();
    sess_b.ctx.set_fault_plan(None);
    assert_eq!(
        resp.error.as_ref().map(|e| e.code()),
        Some("worker_panic"),
        "{:?}",
        resp.error
    );
    let restored = sess_b.take(blob).expect("victim row restored through the spill tier");
    let out_b = serve_token(&coord_b, &sess_b, sid_b, &mech, restored, stream, false);
    for (i, (a, b)) in out_a.iter().zip(&out_b).enumerate() {
        assert_eq!(a.ct, b.ct, "replayed step output {i}: spilled == in-memory");
    }
    // The full streamed KV-cache bundles match bit for bit.
    let ea = coord_a.session_store().take(sid_a, stream).expect("A's bundle live");
    let eb = coord_b.session_store().take(sid_b, stream).expect("B's bundle rehydrates");
    assert_eq!(ea.cached_len, t_total);
    assert_eq!(eb.cached_len, t_total);
    assert_eq!(ea.cts.len(), eb.cts.len());
    for (i, (a, b)) in ea.cts.iter().zip(&eb.cts).enumerate() {
        assert_eq!(a.ct, b.ct, "cache ct {i}: spilled == in-memory");
    }
    coord_a.session_store().restore(sid_a, stream, ea);
    coord_b.session_store().restore(sid_b, stream, eb);

    // Teardown: the session leaves zero bundles, zero bytes, and an
    // empty sink behind, and the gauges agree (the drop_session leak
    // regression).
    drop(sess_b);
    assert!(coord_b.drop_session(sid_b));
    assert_eq!(coord_b.session_store().live_blobs(), 0);
    assert_eq!(coord_b.session_store().live_bytes(), 0);
    assert_eq!(coord_b.keymgr.storage().live_blobs(), 0);
    assert_eq!(coord_b.keymgr.storage().sink().len(), 0, "no orphaned sink files");
    assert_eq!(coord_b.metrics().cache_blobs_live.load(Ordering::Relaxed), 0);
    assert_eq!(coord_b.metrics().cache_bytes.load(Ordering::Relaxed), 0);
    assert!(!coord_b.drop_session(sid_b), "second teardown is a no-op");
    std::fs::remove_dir_all(&dir).ok();
}
