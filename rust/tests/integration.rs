//! Cross-module integration tests: quantized model ↔ attention engines,
//! weights round trip through disk, optimizer ↔ tfhe params.

use inhibitor::attention::{common, AttnConfig, Mechanism};
use inhibitor::model::{weights, ModelConfig, ModelInput, QTransformer, TaskHead};
use inhibitor::quant::QParams;
use inhibitor::tensor::{FTensor, ITensor};
use inhibitor::util::prng::Xoshiro256;

#[test]
fn quantized_attention_agrees_with_float_reference_across_sizes() {
    // The Table 3 engines vs ref.py-equivalent float math, across the
    // paper's size sweep (scaled down for test time).
    let mut rng = Xoshiro256::new(1);
    for &(t, d) in &[(8usize, 8usize), (32, 16), (64, 32)] {
        let qf = FTensor::randn(&[t, d], 1.0, &mut rng);
        let kf = FTensor::randn(&[t, d], 1.0, &mut rng);
        let vf = FTensor::randn(&[t, d], 1.0, &mut rng).map(|x| x.abs());
        let qp = QParams::fit_symmetric(4.0, 14);
        let cfg = AttnConfig::new(Mechanism::Inhibitor, t, d);
        let head = inhibitor::attention::InhibitorHead::from_config(cfg, qp.scale, false);
        let h = qp.dequantize_tensor(&head.forward(
            &qp.quantize_tensor(&qf),
            &qp.quantize_tensor(&kf),
            &qp.quantize_tensor(&vf),
        ));
        let want = common::ref_inhibitor(&qf, &kf, &vf, cfg.effective_gamma(), cfg.alpha);
        let tol = qp.scale * (t as f32) * (d as f32);
        assert!(h.max_abs_diff(&want) < tol, "T={t} d={d}: {}", h.max_abs_diff(&want));
    }
}

#[test]
fn weights_roundtrip_through_disk_and_model_builds() {
    let dir = std::env::temp_dir().join(format!("inh_w_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.weights.bin");
    // Build a synthetic weight map mirroring aot.py's export names.
    let mut rng = Xoshiro256::new(5);
    let (d, ffn) = (8usize, 16usize);
    let mut w = weights::WeightMap::new();
    let mut lin = |name: &str,
                   dout: usize,
                   din: usize,
                   rng: &mut Xoshiro256,
                   w: &mut weights::WeightMap| {
        w.insert(format!("{name}.w"), FTensor::randn(&[dout, din], 0.3, rng));
        w.insert(format!("{name}.b"), FTensor::zeros(&[dout]));
    };
    lin("in_proj", d, 2, &mut rng, &mut w);
    for p in ["block0.wq", "block0.wk", "block0.wv", "block0.wo"] {
        lin(p, d, d, &mut rng, &mut w);
    }
    lin("block0.ffn.fc1", ffn, d, &mut rng, &mut w);
    lin("block0.ffn.fc2", d, ffn, &mut rng, &mut w);
    for p in ["block0.ln1", "block0.ln2"] {
        w.insert(format!("{p}.gamma"), FTensor::from_vec(&[d], vec![1.0; d]));
        w.insert(format!("{p}.beta"), FTensor::zeros(&[d]));
    }
    lin("head", 2, d, &mut rng, &mut w);
    weights::save_weights_file(&w, path.to_str().unwrap()).unwrap();
    let w2 = weights::load_weights_file(path.to_str().unwrap()).unwrap();
    assert_eq!(w, w2);
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 4, d);
    cfg.in_features = 2;
    cfg.head = TaskHead::Classify(2);
    let model = weights::build_model(&cfg, &w2).unwrap();
    let x = ITensor::random(&[4, 2], -50, 50, &mut rng);
    let out = model.forward(&ModelInput::Features(x));
    assert_eq!(out.dims(), &[1, 2]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exported_artifact_weights_load_when_present() {
    // When `make artifacts` has run, the real exported weights must load
    // and build the model that matches the manifest config.
    let path = "artifacts/model_inhibitor.weights.bin";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} missing (run `make artifacts`)");
        return;
    }
    let w = weights::load_weights_file(path).unwrap();
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 16, 32);
    cfg.in_features = 2;
    let model = weights::build_model(&cfg, &w).unwrap();
    let mut rng = Xoshiro256::new(3);
    let x = ITensor::random(&[16, 2], -100, 100, &mut rng);
    let out = model.forward(&ModelInput::Features(x));
    assert_eq!(out.dims(), &[1, 1]);
}

#[test]
fn optimizer_params_actually_decode_under_the_real_scheme() {
    // The parameter sets the optimizer emits must work when *executed*:
    // encrypt, bootstrap with an identity LUT, decrypt — exact for every
    // message. (Scaled-down lwe_dim for test time; noise kept at the
    // big-n level, so the noise/margin relation only improves.)
    use inhibitor::optimizer::{optimize, profile, SearchConfig};
    use inhibitor::tfhe::{bootstrap::Lut, ClientKey, Encoder};
    let prof = profile(Mechanism::Inhibitor, 2, 2, 3);
    let opt = optimize(&prof, SearchConfig::default()).expect("feasible params");
    let mut p = opt.params;
    p.lwe_dim = 256;
    let mut rng = Xoshiro256::new(11);
    let ck = ClientKey::generate(p, &mut rng);
    let sk = ck.server_key(&mut rng);
    let enc = Encoder::new(p);
    let lut = Lut::from_fn(&p, |m| m);
    for m in 0..p.message_space().min(16) {
        let out = enc.decrypt_raw(&sk.pbs(&enc.encrypt_raw(m, &ck, &mut rng), &lut), &ck);
        assert_eq!(out, m, "optimizer-selected params must decode m={m}");
    }
}

#[test]
fn full_stack_quant_model_both_mechanisms_same_input() {
    // Smoke the model across mechanisms with identical inputs and confirm
    // outputs are finite, in-range, and mechanism-dependent.
    let mut rng = Xoshiro256::new(21);
    let x = ITensor::random(&[16, 16], -80, 80, &mut rng);
    let mut outs = Vec::new();
    for m in [Mechanism::DotProduct, Mechanism::Inhibitor, Mechanism::InhibitorSigned] {
        let cfg = ModelConfig::small(m, 16, 16);
        let model = QTransformer::random(cfg, 777);
        let out = model.forward(&ModelInput::Features(x.clone()));
        out.check_bits(32).unwrap();
        outs.push(out.data[0]);
    }
    assert!(outs[0] != outs[1] || outs[1] != outs[2], "mechanisms should differ: {outs:?}");
}
