//! Differential test harness for the encrypted transformer **block**
//! subsystem (`fhe_circuits::BlockFhe` / `ModelFhe`).
//!
//! * **Differential grid**: over mechanism × heads ∈ {1, 2} × layers ∈
//!   {1, 2} (plus shared-KV points), the fused L-layer encrypted plan
//!   must decode **bit-identical** to the plaintext reference — both
//!   `ModelFhe::mirror` and a stack of genuine `model::Block` layer
//!   objects (`QLinear`/`QFfn` forwards + the multi-head attention
//!   mirror) built from the same weights — with rewrites off (raw
//!   builder plan) *and* on (full pipeline), at 1 and 4 PBS worker
//!   threads, with every `PBS_COUNT`/`BLIND_ROTATION_COUNT` delta
//!   matching the executed plan's own prediction and the rewritten
//!   plan's counts matching `optimizer::precision::profile_block`'s
//!   closed forms. `forward()` (cached `plan_for`, honors
//!   `FHE_NO_REWRITE`) is exercised on every point, so the CI no-rewrite
//!   leg drives the unrewritten block pipeline end to end here.
//! * **Cross-layer rewrite win**: the stacked L = 2 signed plan vs two
//!   separately-rewritten single-block plans — LUT evaluations pinned
//!   equal, blind rotations pinned equal at ϑ = 1 and exactly `T·d_kv`
//!   lower for the stacked plan at ϑ ≥ 2 (the requant + ReLU + split
//!   trios on the layer boundary), including a packed-group-of-3
//!   assertion under the `test_multi_lut_theta(·, 2)` parameter set.
//! * **ϑ = 2 end to end**: a real forward on the ϑ = 2 keyset executes
//!   the trios in genuinely packed rotations and still decodes exactly.
//! * **Serving**: co-scheduled block requests ride the router's fused
//!   level executor through `Coordinator::add_fhe_block_engine`, come
//!   back bit-identical to solo plan execution, and return their
//!   encrypted results as typed `result_blob` references.
//!
//! Counters are process-global and libtest runs tests on parallel
//! threads, so every test serializes through one lock.

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{BatchPolicy, Coordinator, EnginePath, Payload, RoutePolicy};
use inhibitor::fhe_circuits::{CtMatrix, ModelFhe};
use inhibitor::model::transformer::Block;
use inhibitor::optimizer::profile_block;
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    bootstrap, rewrites_disabled, ClientKey, FheContext, PlanRewriter, RewriteConfig, TfheParams,
};
use inhibitor::util::prng::Xoshiro256;
use std::sync::Mutex;
use std::time::Duration;

static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// One differential grid point: a demo-weight model on random x ∈
/// [−1, 1] (every linear intermediate provably fits the keyset's signed
/// code range, so mirror equality is exact), executed through the raw
/// plan, the fully-rewritten plan, and `forward()`, at 1 and 4 worker
/// threads, with plan-predicted counter deltas and closed-form pins.
#[allow(clippy::too_many_arguments)]
fn check_point(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    mech: Mechanism,
    heads: usize,
    layers: usize,
    t: usize,
    d: usize,
    shared_kv: bool,
) {
    let tag = format!("{mech:?} H={heads} L={layers} T={t} d={d} shared={shared_kv}");
    let dm = heads * d;
    let model = ModelFhe::demo(mech, dm, heads, layers, shared_kv, dm, 0xB10C + layers as u64);
    let x = ITensor::random(&[t, dm], -1, 1, rng);
    let cx = CtMatrix::encrypt(&x, ctx, ck, rng);
    let (min_s, max_s) = (ctx.enc.min_signed(), ctx.enc.max_signed());
    let want = model.mirror(&x, min_s, max_s);
    // The plaintext QTransformer-side stack must agree with the mirror
    // bit for bit (the acceptance bar's reference). The bridge has one
    // definition each way: `BlockWeights::to_model_block` for the
    // layers, `ModelFhe::reference_stack` for the dataflow.
    let blocks: Vec<Block> =
        model.blocks.iter().map(|b| b.weights.to_model_block(mech, heads)).collect();
    let stack = model.reference_stack(&blocks, &x, min_s, max_s);
    assert_eq!(want, stack, "{tag}: ModelFhe::mirror vs model::Block stack");
    // Plans + closed forms: the rewritten plan's counts must equal
    // profile_block at the executing budget (and the raw plan is already
    // duplicate-free for the inhibitors).
    let raw = model.plan(t);
    let (rewritten, _) = PlanRewriter::for_ctx(ctx).rewrite(model.plan(t));
    let prof = profile_block(mech, t, dm, heads, layers, dm, shared_kv, ctx.max_multi_lut());
    assert_eq!(rewritten.pbs_count(), prof.pbs_count, "{tag}: closed-form LUT evals");
    assert_eq!(
        rewritten.blind_rotation_count(),
        prof.blind_rotations,
        "{tag}: closed-form rotations"
    );
    assert_eq!(rewritten.levels() as u64, prof.levels, "{tag}: closed-form levels");
    if mech != Mechanism::DotProduct {
        assert_eq!(raw.pbs_count(), prof.pbs_count, "{tag}: raw emission is duplicate-free");
    }
    let refs = model.input_refs(&cx);
    for threads in [1usize, 4] {
        ctx.set_threads(threads);
        for (label, plan) in [("raw", &raw), ("rewritten", &rewritten)] {
            let before_pbs = bootstrap::pbs_count();
            let before_rot = bootstrap::blind_rotation_count();
            let outs = plan.execute_ref(ctx, &refs);
            assert_eq!(
                bootstrap::pbs_count() - before_pbs,
                plan.pbs_count(),
                "{tag} {label} threads={threads}: PBS delta"
            );
            assert_eq!(
                bootstrap::blind_rotation_count() - before_rot,
                plan.blind_rotation_count(),
                "{tag} {label} threads={threads}: rotation delta"
            );
            let got: Vec<i64> = outs.iter().map(|c| ctx.decrypt(c, ck)).collect();
            assert_eq!(got, want.data, "{tag} {label} threads={threads}: mirror equality");
        }
        // The serving path: cached plan_for (honors FHE_NO_REWRITE, so
        // the CI matrix leg drives the unrewritten pipeline through
        // here) — same decode either way.
        let fwd = model.forward(ctx, &cx);
        assert_eq!((fwd.rows, fwd.cols), (t, dm), "{tag}: forward shape");
        assert_eq!(fwd.decrypt(ctx, ck), want, "{tag} forward threads={threads}");
    }
}

#[test]
fn block_inhibitor_matches_plaintext_reference_over_grid() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C01);
    // 5-bit signed range [−16, 15]: demo weights on x ∈ [−1, 1] keep
    // every linear intermediate within it for T ≤ 3, L ≤ 2.
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    for &(heads, layers, t, d, shared) in &[
        (1usize, 1usize, 2usize, 2usize, false),
        (2, 1, 3, 1, false),
        (1, 2, 2, 2, false),
        (2, 2, 2, 1, false),
        (2, 1, 2, 2, true),
    ] {
        check_point(&ctx, &ck, &mut rng, Mechanism::Inhibitor, heads, layers, t, d, shared);
    }
}

#[test]
fn block_signed_inhibitor_matches_plaintext_reference_over_grid() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C02);
    // Packing-capable keyset (ϑ = 1 at 5 bits): the layer-0 split pairs
    // and the boundary trios execute genuinely packed rotations.
    let ck = ClientKey::generate(TfheParams::test_multi_lut(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    assert_eq!(ctx.max_multi_lut(), 2);
    for &(heads, layers, t, d, shared) in &[
        (1usize, 1usize, 2usize, 2usize, false),
        (1, 2, 2, 2, false),
        (2, 1, 2, 1, false),
        (2, 2, 2, 1, false),
        (2, 1, 2, 2, true),
    ] {
        check_point(
            &ctx,
            &ck,
            &mut rng,
            Mechanism::InhibitorSigned,
            heads,
            layers,
            t,
            d,
            shared,
        );
    }
}

#[test]
fn block_dotprod_matches_plaintext_reference_over_grid() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C03);
    // 6-bit range [−32, 31]: covers the exp/probability codes and every
    // square-LUT operand of both layers on demo-weight ranges.
    let ck = ClientKey::generate(TfheParams::test_for_bits(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    for &(heads, layers, t, d, shared) in &[
        (1usize, 1usize, 2usize, 2usize, false),
        (1, 2, 2, 2, false),
        (2, 1, 2, 1, false),
        (2, 2, 2, 1, false),
        (2, 1, 2, 2, true),
    ] {
        check_point(&ctx, &ck, &mut rng, Mechanism::DotProduct, heads, layers, t, d, shared);
    }
}

#[test]
fn stacked_plan_beats_two_separate_block_plans_at_theta2() {
    // The cross-layer analogue of PR 4's (H−1)·T·d pin — pure DAG
    // analysis. LUT evaluations are identical either way (folding moves
    // tables, never adds them); at ϑ = 1 the rotations tie (pairs pack
    // in both shapes); at ϑ ≥ 2 the stacked plan wins exactly the
    // boundary trios: (L−1)·T·d_kv fewer blind rotations.
    let _g = lock();
    for &(heads, t, d, shared) in
        &[(1usize, 2usize, 2usize, false), (2, 2, 1, false), (2, 2, 2, true), (2, 3, 2, false)]
    {
        let dm = heads * d;
        let layers = 2usize;
        let model = ModelFhe::demo(Mechanism::InhibitorSigned, dm, heads, layers, shared, dm, 7);
        let single_a = ModelFhe::new(vec![model.blocks[0].clone()]);
        let single_b = ModelFhe::new(vec![model.blocks[1].clone()]);
        let vcols = if shared { d } else { dm };
        let nv = (t * vcols) as u64;
        let tag = format!("H={heads} T={t} d={d} shared={shared}");
        for budget in [2usize, 4] {
            let rewriter = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: budget });
            let (fused, _) = rewriter.rewrite(model.plan(t));
            let (sa, _) = rewriter.rewrite(single_a.plan(t));
            let (sb, _) = rewriter.rewrite(single_b.plan(t));
            let sep_luts = sa.pbs_count() + sb.pbs_count();
            let sep_rot = sa.blind_rotation_count() + sb.blind_rotation_count();
            assert_eq!(fused.pbs_count(), sep_luts, "{tag} budget={budget}: LUT evals tie");
            if budget >= 4 {
                assert_eq!(
                    sep_rot - fused.blind_rotation_count(),
                    nv,
                    "{tag}: the ϑ ≥ 2 win is exactly the boundary trios"
                );
                // The trio groups exist as genuine 3-member MultiPbs
                // nodes — the first ≥ 3-distinct-LUTs-per-input packs
                // the IR has ever formed.
                let sizes = fused.multi_group_sizes();
                assert_eq!(
                    sizes.iter().filter(|&&s| s == 3).count() as u64,
                    nv,
                    "{tag}: one trio per boundary value element"
                );
                assert!(sa.multi_group_sizes().iter().all(|&s| s == 2), "{tag}: solo plans pair");
            } else {
                assert_eq!(
                    fused.blind_rotation_count(),
                    sep_rot,
                    "{tag}: ϑ = 1 cannot see past the pairwise packing"
                );
            }
            // Closed forms agree with the profiles at both budgets.
            let prof =
                profile_block(Mechanism::InhibitorSigned, t, dm, heads, 2, dm, shared, budget);
            assert_eq!(fused.pbs_count(), prof.pbs_count, "{tag} budget={budget}");
            assert_eq!(fused.blind_rotation_count(), prof.blind_rotations, "{tag} {budget}");
        }
    }
}

#[test]
fn theta2_forward_executes_packed_trios_and_decodes_exactly() {
    // Real crypto on the ϑ = 2 keyset: the L = 2 signed stack executes
    // its requant + ReLU + split trios in one rotation each, counters
    // match the executed plan (and, with rewrites enabled, the ϑ = 2
    // closed forms), and the decode is bit-identical to the plaintext
    // reference.
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C04);
    let ck = ClientKey::generate(TfheParams::test_multi_lut_theta(5, 2), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    assert_eq!(ctx.max_multi_lut(), 4);
    let (heads, layers, t, d) = (1usize, 2usize, 2usize, 2usize);
    let dm = heads * d;
    let model = ModelFhe::demo(Mechanism::InhibitorSigned, dm, heads, layers, false, dm, 11);
    let x = ITensor::random(&[t, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &ctx, &ck, &mut rng);
    let want = model.mirror(&x, ctx.enc.min_signed(), ctx.enc.max_signed());
    // The plan forward() will execute (rewritten unless the CI
    // no-rewrite leg is driving): its own counts are the prediction.
    let plan = model.plan_for(&ctx, t);
    let before_pbs = bootstrap::pbs_count();
    let before_rot = bootstrap::blind_rotation_count();
    let fwd = model.forward(&ctx, &cx);
    assert_eq!(bootstrap::pbs_count() - before_pbs, plan.pbs_count(), "PBS delta");
    assert_eq!(
        bootstrap::blind_rotation_count() - before_rot,
        plan.blind_rotation_count(),
        "rotation delta"
    );
    assert_eq!(fwd.decrypt(&ctx, &ck), want, "ϑ = 2 packed execution decodes exactly");
    if !rewrites_disabled() {
        // With the pipeline on, the executed plan IS the ϑ = 2 form:
        // groups of 3 on the layer boundary, closed-form counts.
        let prof = profile_block(Mechanism::InhibitorSigned, t, dm, heads, layers, dm, false, 4);
        assert_eq!(plan.pbs_count(), prof.pbs_count, "ϑ = 2 closed-form LUT evals");
        assert_eq!(plan.blind_rotation_count(), prof.blind_rotations, "ϑ = 2 rotations");
        assert!(
            plan.multi_group_sizes().iter().any(|&s| s >= 3),
            "the executed plan must carry a packed group of ≥ 3 LUTs"
        );
    }
}

#[test]
fn block_engine_serves_coscheduled_requests_through_fusion() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C05);
    let (heads, layers, t, d) = (1usize, 2usize, 2usize, 2usize);
    let dm = heads * d;
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    let model = ModelFhe::demo(Mechanism::Inhibitor, dm, heads, layers, false, dm, 5);
    let n_req = 2usize;
    coord
        .add_fhe_block_engine(
            session,
            model.clone(),
            t,
            BatchPolicy { max_batch: n_req, max_wait: Duration::from_secs(2), queue_cap: 64 },
        )
        .unwrap();
    let sess = coord.keymgr.session(session).unwrap();
    // The engine resolves the same cached-plan construction on its own
    // worker (the clone shares the model's plan cache); PBS is
    // deterministic, so solo executions of this plan are the reference.
    let plan = model.plan_for(&sess.ctx, t);
    let mut tensors = Vec::with_capacity(n_req);
    let mut bundles: Vec<Vec<CtInt>> = Vec::with_capacity(n_req);
    for _ in 0..n_req {
        let x = ITensor::random(&[t, dm], -1, 1, &mut rng);
        let cx = CtMatrix::encrypt(&x, &sess.ctx, &ck, &mut rng);
        // Wire layout = plan-input layout, defined once by input_refs.
        bundles.push(model.input_refs(&cx).into_iter().cloned().collect());
        tensors.push(x);
    }
    let solo: Vec<Vec<CtInt>> = bundles.iter().map(|b| plan.execute(&sess.ctx, b)).collect();
    let path = EnginePath::Encrypted { session, mechanism: model.engine_mechanism() };
    let rxs: Vec<_> = bundles
        .iter()
        .map(|b| {
            let blob = sess.register(b.clone());
            coord.submit(path.clone(), Payload::CiphertextRef(blob)).unwrap()
        })
        .collect();
    let resps: Vec<_> =
        rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(600)).unwrap()).collect();
    for resp in &resps {
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    // Both requests rode ONE fused batch: one fused submission per plan
    // level of the whole L-layer stack.
    let m = coord.metrics();
    assert_eq!(
        m.fused_levels.load(std::sync::atomic::Ordering::Relaxed),
        plan.levels() as u64,
        "co-scheduled block requests must fuse at level granularity"
    );
    for (r, resp) in resps.iter().enumerate() {
        let blob = resp.result_blob.expect("typed result reference");
        assert!(resp.output.is_empty(), "blob ids must not ride the f32 vector");
        let cts = sess.take(blob).unwrap();
        assert_eq!(cts.len(), t * dm);
        for (i, (got, want)) in cts.iter().zip(&solo[r]).enumerate() {
            assert_eq!(got.ct, want.ct, "request {r} output {i}: fused == solo");
        }
        let mirror =
            model.mirror(&tensors[r], sess.ctx.enc.min_signed(), sess.ctx.enc.max_signed());
        let got: Vec<i64> = cts.iter().map(|c| sess.ctx.decrypt(c, &ck)).collect();
        assert_eq!(got, mirror.data, "request {r}: plaintext block-stack reference");
    }
    assert_eq!(model.plan_builds(), 1, "reference plan built once from the shared cache");
}

#[test]
fn wide_output_block_decodes_canonical_limbs() {
    // A declared 9-bit output accumulator on the final residual: the
    // radix legalization pass widens the stack's outputs into canonical
    // limbs with no per-circuit changes beyond the declaration. On this
    // 6-bit keyset legalization fires natively (3-bit limbs, k = 3);
    // under the forced-radix CI leg (`FHE_RADIX_NATIVE_BITS=5`) it fires
    // at the forced width instead (2-bit limbs, k = 5) — either way the
    // limbs must decode to the exact wide mirror.
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C07);
    let ck = ClientKey::generate(TfheParams::test_multi_lut(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (heads, layers, t, d) = (1usize, 2usize, 2usize, 2usize);
    let dm = heads * d;
    let model = ModelFhe::demo(Mechanism::InhibitorSigned, dm, heads, layers, false, dm, 13)
        .with_accumulator_bits(9);
    let x = ITensor::random(&[t, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &ctx, &ck, &mut rng);
    let want = model.mirror(&x, ctx.enc.min_signed(), ctx.enc.max_signed());
    let plan = model.plan_for(&ctx, t);
    let info = plan
        .radix()
        .expect("a 9-bit accumulator exceeds every CI leg's native space")
        .clone();
    let before_pbs = bootstrap::pbs_count();
    let fwd = model.forward(&ctx, &cx);
    assert_eq!(bootstrap::pbs_count() - before_pbs, plan.pbs_count(), "PBS delta");
    let limbs = info.spec.limbs;
    assert_eq!((fwd.rows, fwd.cols), (t, dm * limbs), "wide output matrix layout");
    for i in 0..t {
        for e in 0..dm {
            let slots: Vec<i64> = (0..limbs)
                .map(|l| ctx.decrypt(&fwd.data[i * dm * limbs + e * limbs + l], &ck))
                .collect();
            assert_eq!(
                slots,
                info.spec.encode(want.data[i * dm + e]),
                "canonical limbs of output ({i}, {e})"
            );
        }
    }
}

#[test]
fn block_plan_cache_builds_once_across_forwards_and_clones() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0xB70C06);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (t, dm) = (2usize, 2usize);
    let model = ModelFhe::demo(Mechanism::Inhibitor, dm, 1, 1, false, dm, 9);
    let x = ITensor::random(&[t, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &ctx, &ck, &mut rng);
    assert_eq!(model.plan_builds(), 0);
    let first = model.forward(&ctx, &cx);
    let second = model.forward(&ctx, &cx);
    assert_eq!(model.plan_builds(), 1, "repeated forwards reuse the cached stacked plan");
    let clone = model.clone();
    let third = clone.forward(&ctx, &cx);
    assert_eq!(clone.plan_builds(), 1, "clones share the cache");
    for (a, b) in first.data.iter().zip(second.data.iter()) {
        assert_eq!(a.ct, b.ct, "cached plan must not change results");
    }
    for (a, b) in first.data.iter().zip(third.data.iter()) {
        assert_eq!(a.ct, b.ct);
    }
}
