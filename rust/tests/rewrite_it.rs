//! Bit-identity harness for the plan rewrite passes: over a seeded
//! `(T, d)` grid, every attention circuit must decrypt to the *same*
//! integers with rewrites off and on, the blind-rotation count must
//! strictly drop wherever packing applies (the signed inhibitor), and
//! the global `PBS_COUNT` / `BLIND_ROTATION_COUNT` deltas must match the
//! `CircuitPlan` predictions exactly in both modes. Circuits the passes
//! cannot touch (unsigned inhibitor, dot-product) must come out
//! ciphertext-identical, not just decode-identical.

use inhibitor::fhe_circuits::{CtMatrix, DotProductFhe, InhibitorFhe, InhibitorSignedFhe};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    bootstrap, CircuitPlan, ClientKey, FheContext, PlanRewriter, RewriteConfig, TfheParams,
};
use inhibitor::util::prng::Xoshiro256;
use std::sync::Mutex;

/// `PBS_COUNT` / `BLIND_ROTATION_COUNT` are process-global and tests in
/// this binary run on parallel threads; count-sensitive tests serialize
/// through this lock.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Execute `plan` and return (decrypted outputs, LUT evaluations, blind
/// rotations), asserting the counter deltas match the plan's own
/// predictions exactly.
fn run_counted(
    plan: &CircuitPlan,
    ctx: &FheContext,
    ck: &ClientKey,
    inputs: &[CtInt],
    label: &str,
) -> (Vec<i64>, Vec<CtInt>) {
    let before_pbs = bootstrap::pbs_count();
    let before_rot = bootstrap::blind_rotation_count();
    let outs = plan.execute(ctx, inputs);
    assert_eq!(
        bootstrap::pbs_count() - before_pbs,
        plan.pbs_count(),
        "{label}: PBS_COUNT must match the plan's pbs_count()"
    );
    assert_eq!(
        bootstrap::blind_rotation_count() - before_rot,
        plan.blind_rotation_count(),
        "{label}: BLIND_ROTATION_COUNT must match the plan's blind_rotation_count()"
    );
    let dec = outs.iter().map(|c| ctx.decrypt(c, ck)).collect();
    (dec, outs)
}

fn encrypt_qkv(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    t: usize,
    d: usize,
    qk_range: (i64, i64),
    v_range: (i64, i64),
) -> (ITensor, ITensor, ITensor, Vec<CtInt>) {
    let q = ITensor::random(&[t, d], qk_range.0, qk_range.1, rng);
    let k = ITensor::random(&[t, d], qk_range.0, qk_range.1, rng);
    let v = ITensor::random(&[t, d], v_range.0, v_range.1, rng);
    let mut inputs = Vec::with_capacity(3 * t * d);
    for tensor in [&q, &k, &v] {
        inputs.extend(tensor.data.iter().map(|&val| ctx.encrypt(val, ck, rng)));
    }
    (q, k, v, inputs)
}

#[test]
fn signed_inhibitor_rewrites_are_bit_identical_and_cut_rotations() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x2E11);
    // One packing-capable keyset for the whole grid (ϑ = 1 at 4 bits).
    let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    assert_eq!(ctx.max_multi_lut(), 2, "grid params must advertise a packing budget");
    // (T, d, q/k range, v range): ranges hand-sized so every
    // intermediate of the signed circuit stays in the 4-bit signed range.
    let grid = [(2usize, 2usize, (-2i64, 1i64), (-3i64, 3i64)), (3, 2, (-1, 1), (-2, 2))];
    for &(t, d, qk_range, v_range) in &grid {
        let head = InhibitorSignedFhe::new(d, 1);
        let raw = head.plan(t, d);
        let rewriter = PlanRewriter::new(RewriteConfig {
            cse: true,
            max_multi_lut: ctx.max_multi_lut(),
        });
        let (rewritten, stats) = rewriter.rewrite(head.plan(t, d));
        // Exact closed forms of the rewrite, pinned per shape.
        let (tu, du) = (t as u64, d as u64);
        assert_eq!(raw.pbs_count(), 5 * tu * tu * du + tu * tu + tu * du, "verbatim T={t}");
        assert_eq!(
            rewritten.pbs_count(),
            3 * tu * tu * du + tu * tu + 3 * tu * du,
            "CSE'd T={t}"
        );
        assert_eq!(
            rewritten.blind_rotation_count(),
            3 * tu * tu * du + tu * tu + 2 * tu * du,
            "packed T={t}"
        );
        assert!(
            rewritten.blind_rotation_count() < raw.blind_rotation_count(),
            "packing applies here, so rotations must strictly drop (T={t}, d={d})"
        );
        assert_eq!(stats.multi_groups, t * d);
        // Same encrypted inputs through both plans.
        let (q, k, v, inputs) = encrypt_qkv(&ctx, &ck, &mut rng, t, d, qk_range, v_range);
        let (dec_raw, _) = run_counted(&raw, &ctx, &ck, &inputs, "signed raw");
        let (dec_rw, _) = run_counted(&rewritten, &ctx, &ck, &inputs, "signed rewritten");
        assert_eq!(dec_raw, dec_rw, "rewritten outputs must be bit-identical (T={t}, d={d})");
        // And both must equal the plaintext mirror.
        let want = head.mirror(&q, &k, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
        assert_eq!(dec_rw, want.data, "mirror equality (T={t}, d={d})");
    }
}

#[test]
fn untouched_circuits_rewrite_to_ciphertext_identical_plans() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x2E12);
    let (t, d) = (2usize, 2usize);
    // Unsigned inhibitor at 5 bits, dot-product at 6 — the widths their
    // e2e tests use. Neither circuit has duplicate or same-input PBS
    // nodes, so the full pipeline must leave counts unchanged and the
    // executions bit-identical down to the ciphertexts.
    {
        let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let head = InhibitorFhe::new(d, 1);
        let raw = head.plan(t, d);
        let (rewritten, stats) =
            PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 4 })
                .rewrite(head.plan(t, d));
        assert_eq!(stats.cse_merged, 0, "inhibitor plan is already duplicate-free");
        assert_eq!(stats.multi_groups, 0, "no same-input LUT pairs to pack");
        assert_eq!(rewritten.pbs_count(), raw.pbs_count());
        assert_eq!(rewritten.blind_rotation_count(), raw.blind_rotation_count());
        let (_, _, _, inputs) = encrypt_qkv(&ctx, &ck, &mut rng, t, d, (-2, 2), (0, 3));
        let (_, outs_raw) = run_counted(&raw, &ctx, &ck, &inputs, "inhibitor raw");
        let (_, outs_rw) = run_counted(&rewritten, &ctx, &ck, &inputs, "inhibitor rewritten");
        for (i, (a, b)) in outs_raw.iter().zip(outs_rw.iter()).enumerate() {
            assert_eq!(a.ct, b.ct, "inhibitor output {i} must be ciphertext-identical");
        }
    }
    {
        let ck = ClientKey::generate(TfheParams::test_for_bits(6), &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let head = DotProductFhe::new(d, 2);
        let raw = head.plan(t, d);
        let (rewritten, stats) =
            PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 4 })
                .rewrite(head.plan(t, d));
        assert_eq!(stats.cse_merged, 0, "dot-product plan is already duplicate-free");
        assert_eq!(stats.multi_groups, 0);
        assert_eq!(rewritten.pbs_count(), raw.pbs_count());
        assert_eq!(rewritten.blind_rotation_count(), raw.blind_rotation_count());
        let mut inputs = Vec::with_capacity(3 * t * d);
        for tensor in [
            ITensor::from_vec(&[t, d], vec![1, -1, 2, 0]),
            ITensor::from_vec(&[t, d], vec![1, 1, -1, 2]),
            ITensor::from_vec(&[t, d], vec![2, 1, -1, 3]),
        ] {
            inputs.extend(tensor.data.iter().map(|&val| ctx.encrypt(val, &ck, &mut rng)));
        }
        let (_, outs_raw) = run_counted(&raw, &ctx, &ck, &inputs, "dotprod raw");
        let (_, outs_rw) = run_counted(&rewritten, &ctx, &ck, &inputs, "dotprod rewritten");
        for (i, (a, b)) in outs_raw.iter().zip(outs_rw.iter()).enumerate() {
            assert_eq!(a.ct, b.ct, "dotprod output {i} must be ciphertext-identical");
        }
    }
}

#[test]
fn forward_executes_rewritten_plan_from_a_warm_cache() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x2E13);
    let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (t, d) = (2usize, 2usize);
    let head = InhibitorSignedFhe::new(d, 1);
    let q = ITensor::from_vec(&[t, d], vec![1, -2, 0, 1]);
    let k = ITensor::from_vec(&[t, d], vec![1, -1, -2, 0]);
    let v = ITensor::from_vec(&[t, d], vec![3, -1, -2, 2]);
    let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
    let ckk = CtMatrix::encrypt(&k, &ctx, &ck, &mut rng);
    let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
    let rewritten = head.plan_for(&ctx, t, d);
    assert_eq!(head.plan_builds(), 1);
    let mut first: Option<Vec<_>> = None;
    for round in 0..2 {
        let before_pbs = bootstrap::pbs_count();
        let before_rot = bootstrap::blind_rotation_count();
        let h = head.forward(&ctx, &cq, &ckk, &cv);
        // forward() must execute exactly the cached rewritten plan.
        assert_eq!(bootstrap::pbs_count() - before_pbs, rewritten.pbs_count(), "round {round}");
        assert_eq!(
            bootstrap::blind_rotation_count() - before_rot,
            rewritten.blind_rotation_count(),
            "round {round}"
        );
        let cts: Vec<_> = h.data.iter().map(|c| c.ct.clone()).collect();
        match &first {
            None => first = Some(cts),
            Some(f) => assert_eq!(f, &cts, "repeated forwards are bit-identical"),
        }
    }
    assert_eq!(head.plan_builds(), 1, "no rebuild across repeated forwards");
}

#[test]
fn packed_execution_is_thread_count_invariant() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x2E14);
    let ck = ClientKey::generate(TfheParams::test_multi_lut(4), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (t, d) = (2usize, 2usize);
    let head = InhibitorSignedFhe::new(d, 1);
    let plan = head.plan_for(&ctx, t, d);
    let (_, _, _, inputs) = encrypt_qkv(&ctx, &ck, &mut rng, t, d, (-2, 1), (-3, 3));
    ctx.set_threads(1);
    let reference = plan.execute(&ctx, &inputs);
    for threads in [2usize, 4] {
        ctx.set_threads(threads);
        let got = plan.execute(&ctx, &inputs);
        for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
            assert_eq!(
                a.ct, b.ct,
                "multi-LUT worker path must be deterministic (threads={threads}, output {i})"
            );
        }
    }
}
