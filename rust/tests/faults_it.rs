//! Fault-injection differential harness (PR 6): serve encrypted batches
//! through the coordinator with deterministic faults armed
//! (`FaultPlan` — the programmatic form of `FHE_FAULTS`) and pin the
//! blast radius:
//!
//!   * an injected PBS worker panic fails ONLY the request that owned
//!     the poisoned job (`worker_panic`), every co-batched survivor's
//!     ciphertexts stay **bit-identical** to a fault-free solo run, the
//!     engine is respawned, and the next request succeeds;
//!   * an injected deadline abandons the victim at a level boundary
//!     (`deadline_exceeded`) having executed strictly fewer PBS levels
//!     than the plan holds (pinned via the global rotation counters);
//!   * an injected wholesale engine panic is quarantined by the
//!     scheduler's supervision and the engine keeps serving.
//!
//! Solo references are computed BEFORE arming the faults: the reference
//! path (`CircuitPlan::execute`) never consults the fault plan, so the
//! comparison is exact.
//!
//! Every test body runs twice — under wavefront dispatch and under the
//! legacy level barrier (PR 8) — because fault indices are assigned by
//! submission order and cancellation ticks fire at wave ≡ level
//! boundaries, so the whole contract must be dispatch-invariant.

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{
    BatchPolicy, Coordinator, EnginePath, InferRequest, InferResponse, Payload, RoutePolicy,
};
use inhibitor::error::FheError;
use inhibitor::fhe_circuits::{CtMatrix, DecodeFhe, InhibitorFhe, ModelFhe};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    bootstrap, set_wavefront_dispatch, ClientKey, FaultPlan, FheContext, TfheParams,
};
use inhibitor::util::prng::{Rng64, Xoshiro256};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// `PBS_COUNT` is process-global and tests in this binary run on parallel
/// threads; count-sensitive tests serialize through this lock.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pins the PBS dispatch mode for a scope and restores the
/// environment-driven default on drop (panic-safe). Every fault test
/// runs its body once per mode: fault indices are submission-order and
/// cancellation ticks sit at wave ≡ level boundaries, so the entire
/// blast-radius contract must be dispatch-invariant (PR 8).
struct WavefrontGuard;

impl WavefrontGuard {
    fn set(mode: bool) -> Self {
        set_wavefront_dispatch(Some(mode));
        WavefrontGuard
    }
}

impl Drop for WavefrontGuard {
    fn drop(&mut self) {
        set_wavefront_dispatch(None);
    }
}

fn encrypt_qkv(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    t: usize,
    d: usize,
) -> Vec<CtInt> {
    (0..3 * t * d)
        .map(|i| {
            let v = if i < 2 * t * d {
                rng.next_range_i64(-2, 2) // q, k codes
            } else {
                rng.next_range_i64(0, 3) // v codes
            };
            ctx.encrypt(v, ck, rng)
        })
        .collect()
}

struct Rig {
    coord: Coordinator,
    session: u64,
    ck: ClientKey,
}

/// Coordinator + session + single-head inhibitor engine (t=2, d=2),
/// batching up to `max_batch` co-scheduled requests.
fn rig(seed: u64, max_batch: usize) -> Rig {
    let mut rng = Xoshiro256::new(seed);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    coord
        .add_fhe_engine(
            session,
            "inhibitor",
            2,
            2,
            BatchPolicy { max_batch, max_wait: Duration::from_secs(2), queue_cap: 64 },
        )
        .unwrap();
    Rig { coord, session, ck }
}

fn fhe_path(session: u64) -> EnginePath {
    EnginePath::Encrypted { session, mechanism: "inhibitor".into() }
}

/// Submit one registered bundle and block for its response.
fn infer(r: &Rig, blob: u64) -> InferResponse {
    let path = fhe_path(r.session);
    r.coord.infer_blocking(path, Payload::CiphertextRef(blob), Duration::from_secs(300)).unwrap()
}

#[test]
fn injected_pbs_panic_fails_only_the_victim_and_survivors_stay_bit_identical() {
    let _g = lock();
    for mode in [true, false] {
        let _m = WavefrontGuard::set(mode);
        pbs_panic_blast_radius();
    }
}

fn pbs_panic_blast_radius() {
    let (t, d) = (2usize, 2usize);
    let r = rig(0xFA017, 3);
    let sess = r.coord.keymgr.session(r.session).unwrap();
    // The engine serves the *rewritten* plan; use the same one for the
    // level layout and the solo references.
    let plan = InhibitorFhe::new(d, 1).plan_for(&sess.ctx, t, d);
    let mut rng = Xoshiro256::new(0xFA018);
    let bundles: Vec<Vec<CtInt>> =
        (0..3).map(|_| encrypt_qkv(&sess.ctx, &r.ck, &mut rng, t, d)).collect();
    // Fault-free solo references, computed BEFORE arming the fault.
    let solo: Vec<Vec<CtInt>> =
        bundles.iter().map(|inputs| plan.execute(&sess.ctx, inputs)).collect();
    // The fused level 1 submits the members' jobs in request order:
    // request 0 owns jobs 1..=s1, request 1 owns s1+1..=2·s1, ... Poison
    // the FIRST job of request 1.
    let s1 = plan.level_sizes()[0] as u64;
    let spec = format!("panic@pbs:{}", s1 + 1);
    sess.ctx.set_fault_plan(Some(Arc::new(FaultPlan::parse(&spec).unwrap())));
    let blobs: Vec<u64> = bundles.iter().map(|b| sess.register(b.clone())).collect();
    let rxs: Vec<_> = blobs
        .iter()
        .map(|&blob| r.coord.submit(fhe_path(r.session), Payload::CiphertextRef(blob)).unwrap())
        .collect();
    let resps: Vec<_> =
        rxs.into_iter().map(|rx| rx.recv_timeout(Duration::from_secs(300)).unwrap()).collect();
    sess.ctx.set_fault_plan(None);
    // Victim: typed WorkerPanic carrying the injected payload.
    match resps[1].error {
        Some(FheError::WorkerPanic(ref m)) => {
            assert!(m.contains(&spec), "panic payload names the injected site: {m}")
        }
        ref other => panic!("victim must fail with WorkerPanic, got {other:?}"),
    }
    assert_eq!(resps[1].error.as_ref().unwrap().code(), "worker_panic");
    // The victim's input bundle was restored for a clean resubmit.
    let restored = sess.take(blobs[1]).expect("victim bundle restored");
    assert_eq!(restored.len(), 3 * t * d);
    // Survivors: error-free and bit-identical to the fault-free run.
    for i in [0usize, 2] {
        assert!(resps[i].error.is_none(), "survivor {i}: {:?}", resps[i].error);
        let cts = sess.take(resps[i].result_blob.expect("typed result reference")).unwrap();
        assert_eq!(cts.len(), solo[i].len());
        for (j, (got, want)) in cts.iter().zip(&solo[i]).enumerate() {
            assert_eq!(got.ct, want.ct, "survivor {i} output {j} must be bit-identical");
        }
    }
    let m = r.coord.metrics();
    assert_eq!(m.quarantined.load(Ordering::Relaxed), 1, "exactly one member quarantined");
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1);
    assert_eq!(m.respawns.load(Ordering::Relaxed), 1, "engine rebuilt after the caught panic");
    // The coordinator keeps serving: the respawned engine handles the
    // victim's resubmission (fault disarmed) bit-identically.
    let blob = sess.register(restored);
    let resp = infer(&r, blob);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let cts = sess.take(resp.result_blob.unwrap()).unwrap();
    for (j, (got, want)) in cts.iter().zip(&solo[1]).enumerate() {
        assert_eq!(got.ct, want.ct, "resubmitted victim output {j}");
    }
}

#[test]
fn injected_deadline_abandons_with_strictly_fewer_pbs_levels() {
    let _g = lock();
    for mode in [true, false] {
        let _m = WavefrontGuard::set(mode);
        deadline_abandons_between_levels();
    }
}

fn deadline_abandons_between_levels() {
    let (t, d) = (2usize, 2usize);
    let r = rig(0xDEAD1, 1);
    let sess = r.coord.keymgr.session(r.session).unwrap();
    let plan = InhibitorFhe::new(d, 1).plan_for(&sess.ctx, t, d);
    assert!(plan.levels() >= 2, "needs at least two levels to abandon between");
    let mut rng = Xoshiro256::new(0xDEAD2);
    let inputs = encrypt_qkv(&sess.ctx, &r.ck, &mut rng, t, d);
    let blob = sess.register(inputs);
    // Boundary ticks: 1 fires before level 1, 2 after it — the member
    // executes exactly one PBS level, then abandons.
    sess.ctx.set_fault_plan(Some(Arc::new(FaultPlan::parse("deadline@level:2").unwrap())));
    let before_rot = bootstrap::blind_rotation_count();
    let before_pbs = bootstrap::pbs_count();
    // A far-future real deadline: only the injected tick can fire, so
    // the test is timing-independent.
    let req = InferRequest::new(0, fhe_path(r.session), Payload::CiphertextRef(blob))
        .with_deadline(Instant::now() + Duration::from_secs(3600));
    let resp = r.coord.infer_request_blocking(req, Duration::from_secs(300)).unwrap();
    sess.ctx.set_fault_plan(None);
    match resp.error {
        Some(FheError::DeadlineExceeded(ref m)) => {
            assert!(m.contains(&format!("1/{}", plan.levels())), "{m}")
        }
        ref other => panic!("want DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(resp.error.as_ref().unwrap().code(), "deadline_exceeded");
    // Strictly fewer PBS levels than the plan holds: exactly level 1 ran.
    let rotations = bootstrap::blind_rotation_count() - before_rot;
    assert_eq!(rotations as usize, plan.level_sizes()[0], "only level 1 rotated");
    assert!(
        bootstrap::pbs_count() - before_pbs < plan.pbs_count(),
        "remaining levels were abandoned"
    );
    let m = r.coord.metrics();
    assert_eq!(m.deadline_kills.load(Ordering::Relaxed), 1);
    // The abandoned request's inputs were restored.
    assert!(sess.take(blob).is_some(), "bundle restored after deadline kill");
    // Fault disarmed: the same engine serves the next request fully.
    let inputs = encrypt_qkv(&sess.ctx, &r.ck, &mut rng, t, d);
    let want = plan.execute(&sess.ctx, &inputs);
    let blob = sess.register(inputs);
    let resp = infer(&r, blob);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let cts = sess.take(resp.result_blob.unwrap()).unwrap();
    for (j, (got, w)) in cts.iter().zip(&want).enumerate() {
        assert_eq!(got.ct, w.ct, "post-deadline output {j}");
    }
}

#[test]
fn injected_engine_panic_is_supervised_and_the_engine_keeps_serving() {
    let _g = lock();
    for mode in [true, false] {
        let _m = WavefrontGuard::set(mode);
        engine_panic_is_supervised();
    }
}

fn engine_panic_is_supervised() {
    let (t, d) = (2usize, 2usize);
    let r = rig(0xE9519, 1);
    let sess = r.coord.keymgr.session(r.session).unwrap();
    let plan = InhibitorFhe::new(d, 1).plan_for(&sess.ctx, t, d);
    let mut rng = Xoshiro256::new(0xE9520);
    let inputs = encrypt_qkv(&sess.ctx, &r.ck, &mut rng, t, d);
    let want = plan.execute(&sess.ctx, &inputs);
    let blob = sess.register(inputs);
    // The engine body's first batch panics wholesale (tick 1); tick 2
    // proceeds. The seam fires BEFORE the bundle is taken, so the blob
    // survives the crash untouched.
    sess.ctx.set_fault_plan(Some(Arc::new(FaultPlan::parse("panic@engine:1").unwrap())));
    let resp = infer(&r, blob);
    match resp.error {
        Some(FheError::WorkerPanic(ref m)) => assert!(m.contains("panic@engine:1"), "{m}"),
        ref other => panic!("want WorkerPanic, got {other:?}"),
    }
    let m = r.coord.metrics();
    assert_eq!(m.respawns.load(Ordering::Relaxed), 1, "supervisor rebuilt the engine body");
    assert_eq!(m.quarantined.load(Ordering::Relaxed), 1);
    // Same blob, same engine, fault plan still armed (tick 2 is clean):
    // the respawned body serves it bit-identically.
    let resp = infer(&r, blob);
    sess.ctx.set_fault_plan(None);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let cts = sess.take(resp.result_blob.unwrap()).unwrap();
    for (j, (got, w)) in cts.iter().zip(&want).enumerate() {
        assert_eq!(got.ct, w.ct, "post-respawn output {j}");
    }
}

/// Coordinator + session + decode engine (single-head inhibitor, L = 1,
/// d_model = 2) plus a solo [`DecodeFhe`] whose plan/PBS determinism
/// makes its streams a bit-identical reference for the served ones.
struct DecodeRig {
    coord: Coordinator,
    session: u64,
    ck: ClientKey,
    decode: DecodeFhe,
}

fn decode_rig(seed: u64) -> DecodeRig {
    let mut rng = Xoshiro256::new(seed);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(ctx);
    let model = ModelFhe::demo(Mechanism::Inhibitor, 2, 1, 1, false, 2, 0xDF);
    let decode = DecodeFhe::new(model.clone());
    coord
        .add_fhe_decode_engine(
            session,
            model,
            BatchPolicy { max_batch: 1, max_wait: Duration::from_secs(2), queue_cap: 64 },
        )
        .unwrap();
    DecodeRig { coord, session, ck, decode }
}

fn decode_path(r: &DecodeRig) -> EnginePath {
    EnginePath::Encrypted { session: r.session, mechanism: r.decode.engine_mechanism() }
}

/// Shared skeleton for the mid-stream decode fault tests: build an
/// unfaulted 3-token reference stream solo, serve the prefill + first
/// step cleanly, fault the SECOND step via `spec`, pin the typed error
/// and the exact pre-step restoration (row bundle AND cache bundle),
/// then disarm, resubmit, and pin the resumed stream bit-identical to
/// the unfaulted reference. Returns the blind-rotation delta measured
/// across the faulted request alone.
fn decode_midstream_fault(r: &DecodeRig, spec: &str, want_code: &str) -> u64 {
    let sess = r.coord.keymgr.session(r.session).unwrap();
    let dm = r.decode.d_model();
    let mut rng = Xoshiro256::new(0xDEC0FA);
    let x = ITensor::random(&[3, dm], -1, 1, &mut rng);
    let cx = CtMatrix::encrypt(&x, &sess.ctx, &r.ck, &mut rng);
    // Unfaulted reference stream, computed solo BEFORE arming anything
    // (plan execution outside the engine never consults the fault plan).
    let x0 = CtMatrix { rows: 1, cols: dm, data: cx.data[..dm].to_vec() };
    let (_, ref_cache0) = r.decode.prefill(&sess.ctx, &x0);
    let row1 = cx.data[dm..2 * dm].to_vec();
    let (ref_row1, ref_cache1) = r.decode.step(&sess.ctx, &row1, ref_cache0);
    let row2 = cx.data[2 * dm..3 * dm].to_vec();
    let (ref_row2, ref_cache2) = r.decode.step(&sess.ctx, &row2, ref_cache1.clone());
    let stream = 9u64;
    // Serve the prefill and the first step cleanly.
    let blob = sess.register(cx.data[..dm].to_vec());
    let req = InferRequest::new(0, decode_path(r), Payload::CiphertextRef(blob))
        .with_cache(None, Some(stream));
    let resp = r.coord.infer_request_blocking(req, Duration::from_secs(300)).unwrap();
    assert!(resp.error.is_none(), "prefill: {:?}", resp.error);
    sess.take(resp.result_blob.unwrap()).unwrap();
    let blob = sess.register(row1);
    let req = InferRequest::new(0, decode_path(r), Payload::CiphertextRef(blob))
        .with_cache(Some(stream), None);
    let resp = r.coord.infer_request_blocking(req, Duration::from_secs(300)).unwrap();
    assert!(resp.error.is_none(), "step 1: {:?}", resp.error);
    let out1 = sess.take(resp.result_blob.unwrap()).unwrap();
    for (i, (a, b)) in out1.iter().zip(&ref_row1).enumerate() {
        assert_eq!(a.ct, b.ct, "served step 1 output {i} == solo reference");
    }
    // Fault the second step. The far-future real deadline means only an
    // injected deadline tick can fire, keeping the test timing-free.
    sess.ctx.set_fault_plan(Some(Arc::new(FaultPlan::parse(spec).unwrap())));
    let before_rot = bootstrap::blind_rotation_count();
    let blob = sess.register(row2.clone());
    let req = InferRequest::new(0, decode_path(r), Payload::CiphertextRef(blob))
        .with_cache(Some(stream), None)
        .with_deadline(Instant::now() + Duration::from_secs(3600));
    let resp = r.coord.infer_request_blocking(req, Duration::from_secs(300)).unwrap();
    sess.ctx.set_fault_plan(None);
    let faulted_rotations = bootstrap::blind_rotation_count() - before_rot;
    assert_eq!(
        resp.error.as_ref().map(|e| e.code()),
        Some(want_code),
        "faulted step: {:?}",
        resp.error
    );
    // The pre-step world came back exactly: the input row bundle …
    let row_back = sess.take(blob).expect("row bundle restored after the fault");
    for (i, (a, b)) in row_back.iter().zip(&row2).enumerate() {
        assert_eq!(a.ct, b.ct, "restored row ct {i}");
    }
    // … and the stream's cache bundle, bit for bit at the pre-step
    // prefix length.
    let entry = r.coord.session_store().take(r.session, stream).expect("cache restored");
    assert_eq!(entry.cached_len, 2, "cache is the post-step-1 bundle");
    assert_eq!(entry.cts.len(), ref_cache1.len());
    for (i, (a, b)) in entry.cts.iter().zip(&ref_cache1).enumerate() {
        assert_eq!(a.ct, b.ct, "restored cache ct {i} == pre-step bundle");
    }
    r.coord.session_store().restore(r.session, stream, entry);
    // Resume: the exact resubmit completes the stream bit-identically to
    // the unfaulted reference — output row and successor cache.
    let blob = sess.register(row_back);
    let req = InferRequest::new(0, decode_path(r), Payload::CiphertextRef(blob))
        .with_cache(Some(stream), None);
    let resp = r.coord.infer_request_blocking(req, Duration::from_secs(300)).unwrap();
    assert!(resp.error.is_none(), "resumed step: {:?}", resp.error);
    let out2 = sess.take(resp.result_blob.unwrap()).unwrap();
    for (i, (a, b)) in out2.iter().zip(&ref_row2).enumerate() {
        assert_eq!(a.ct, b.ct, "resumed step output {i} == unfaulted reference");
    }
    let entry = r.coord.session_store().take(r.session, stream).unwrap();
    assert_eq!(entry.cached_len, 3);
    for (i, (a, b)) in entry.cts.iter().zip(&ref_cache2).enumerate() {
        assert_eq!(a.ct, b.ct, "resumed cache ct {i} == unfaulted reference");
    }
    faulted_rotations
}

#[test]
fn decode_step_deadline_restores_the_cache_and_the_stream_resumes_exactly() {
    let _g = lock();
    for mode in [true, false] {
        let _m = WavefrontGuard::set(mode);
        decode_deadline_midstream();
    }
}

fn decode_deadline_midstream() {
    let r = decode_rig(0xDEAD3);
    let sess = r.coord.keymgr.session(r.session).unwrap();
    // Boundary ticks: 1 fires before level 1, 2 after it — the faulted
    // step executes exactly one PBS level, then abandons.
    let plan = r.decode.step_plan_for(&sess.ctx, 2);
    assert!(plan.levels() >= 2, "needs at least two levels to abandon between");
    let rotations = decode_midstream_fault(&r, "deadline@level:2", "deadline_exceeded");
    assert_eq!(
        rotations as usize,
        plan.level_sizes()[0],
        "the faulted step rotated exactly its first PBS level"
    );
    let m = r.coord.metrics();
    assert_eq!(m.deadline_kills.load(Ordering::Relaxed), 1);
    assert_eq!(m.decode_steps.load(Ordering::Relaxed), 2, "clean + resumed steps counted");
}

#[test]
fn decode_step_pbs_panic_restores_the_cache_and_the_stream_resumes_exactly() {
    let _g = lock();
    for mode in [true, false] {
        let _m = WavefrontGuard::set(mode);
        decode_pbs_panic_midstream();
    }
}

fn decode_pbs_panic_midstream() {
    let r = decode_rig(0xFA019);
    decode_midstream_fault(&r, "panic@pbs:1", "worker_panic");
    let m = r.coord.metrics();
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1, "exactly one poisoned job");
    assert_eq!(m.quarantined.load(Ordering::Relaxed), 1, "only the victim member quarantined");
    assert_eq!(m.respawns.load(Ordering::Relaxed), 1, "engine rebuilt after the caught panic");
    assert_eq!(m.decode_steps.load(Ordering::Relaxed), 2, "clean + resumed steps counted");
}

#[test]
fn armed_but_never_firing_faults_leave_serving_bit_identical() {
    // The CI fault leg runs the whole encrypted suite with
    // FHE_FAULTS=panic@pbs:999999999 — armed checks, no fire. Pin the
    // same invariant directly: the checked path with an armed plan is
    // bit-identical to the solo reference.
    let _g = lock();
    for mode in [true, false] {
        let _m = WavefrontGuard::set(mode);
        armed_but_idle_is_bit_identical();
    }
}

fn armed_but_idle_is_bit_identical() {
    let (t, d) = (2usize, 2usize);
    let r = rig(0xC1EA9, 2);
    let sess = r.coord.keymgr.session(r.session).unwrap();
    let plan = InhibitorFhe::new(d, 1).plan_for(&sess.ctx, t, d);
    let mut rng = Xoshiro256::new(0xC1EB0);
    let inputs = encrypt_qkv(&sess.ctx, &r.ck, &mut rng, t, d);
    let want = plan.execute(&sess.ctx, &inputs);
    sess.ctx
        .set_fault_plan(Some(Arc::new(FaultPlan::parse("panic@pbs:999999999").unwrap())));
    let blob = sess.register(inputs);
    let resp = infer(&r, blob);
    sess.ctx.set_fault_plan(None);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let cts = sess.take(resp.result_blob.unwrap()).unwrap();
    for (j, (got, w)) in cts.iter().zip(&want).enumerate() {
        assert_eq!(got.ct, w.ct, "armed-but-idle output {j}");
    }
    let m = r.coord.metrics();
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 0);
    assert_eq!(m.quarantined.load(Ordering::Relaxed), 0);
    assert_eq!(m.respawns.load(Ordering::Relaxed), 0);
}
