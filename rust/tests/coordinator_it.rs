//! Coordinator + server integration: full TCP round trips, batching under
//! concurrency, backpressure, metrics, encrypted path through the
//! coordinator, graceful shutdown.

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{BatchPolicy, Coordinator, EnginePath, Payload, RoutePolicy};
use inhibitor::model::{ModelConfig, QTransformer};
use inhibitor::server::Client;
use inhibitor::util::prng::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

fn quant_coordinator() -> Coordinator {
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    let mut cfg = ModelConfig::small(Mechanism::Inhibitor, 8, 16);
    cfg.in_features = 4;
    c.add_quant_engine(
        "inhibitor",
        QTransformer::random(cfg, 3),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2), queue_cap: 1024 },
    );
    c
}

#[test]
fn tcp_server_roundtrip_ping_infer_metrics_shutdown() {
    let coord = Arc::new(quant_coordinator());
    let (tx, rx) = std::sync::mpsc::channel();
    let server = {
        let c = Arc::clone(&coord);
        std::thread::spawn(move || {
            inhibitor::server::serve(c, "127.0.0.1:0", move |a| {
                let _ = tx.send(a);
            })
        })
    };
    let addr = rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert!(client.ping().unwrap());
    let (out, lat) = client
        .infer("quant", "inhibitor", vec![0.1; 32], 8, 4)
        .unwrap()
        .expect("inference ok");
    assert_eq!(out.len(), 1);
    assert!(lat >= 0.0);
    // Malformed request surfaces an error, not a disconnect.
    let err = client.infer("quant", "inhibitor", vec![0.1; 5], 8, 4).unwrap();
    assert!(err.is_err());
    let metrics = client.metrics().unwrap();
    assert!(metrics.contains("completed="), "{metrics}");
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn concurrent_clients_all_served_and_batched() {
    let coord = Arc::new(quant_coordinator());
    let (tx, rx) = std::sync::mpsc::channel();
    let server = {
        let c = Arc::clone(&coord);
        std::thread::spawn(move || {
            inhibitor::server::serve(c, "127.0.0.1:0", move |a| {
                let _ = tx.send(a);
            })
        })
    };
    let addr = rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();
    let mut handles = Vec::new();
    for t in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            for i in 0..25 {
                let x = (t * 25 + i) as f32 * 0.01;
                let r = client.infer("quant", "inhibitor", vec![x; 32], 8, 4).unwrap();
                assert!(r.is_ok(), "{r:?}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics();
    assert_eq!(m.completed.load(std::sync::atomic::Ordering::Relaxed), 150);
    let mut shut = Client::connect(&addr).unwrap();
    shut.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn deterministic_outputs_for_identical_requests() {
    let c = quant_coordinator();
    let payload = || Payload::Features(vec![0.25; 32], (8, 4));
    let a = c
        .infer_blocking(EnginePath::QuantInt("inhibitor".into()), payload(), Duration::from_secs(10))
        .unwrap();
    let b = c
        .infer_blocking(EnginePath::QuantInt("inhibitor".into()), payload(), Duration::from_secs(10))
        .unwrap();
    assert_eq!(a.output, b.output);
}

#[test]
fn encrypted_path_through_coordinator() {
    use inhibitor::tfhe::{ClientKey, FheContext, TfheParams};
    let mut rng = Xoshiro256::new(77);
    let ck = ClientKey::generate(TfheParams::test_for_bits(5), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    let session = c.keymgr.create_session(ctx);
    c.add_fhe_engine(session, "inhibitor", 2, 2, BatchPolicy::default()).unwrap();
    let sess = c.keymgr.session(session).unwrap();
    // Drive blob ids past the retired f32-exact 2^24 protocol limit: the
    // typed result reference must round-trip exactly regardless.
    sess.set_next_blob_id((1u64 << 24) + 5);
    let vals = [1i64, -1, 0, 2, 1, 1, -2, 0, 3, 1, 2, 0];
    let bundle: Vec<_> = vals.iter().map(|&v| sess.ctx.encrypt(v, &ck, &mut rng)).collect();
    let blob = sess.register(bundle);
    assert!(blob >= (1u64 << 24));
    let resp = c
        .infer_blocking(
            EnginePath::Encrypted { session, mechanism: "inhibitor".into() },
            Payload::CiphertextRef(blob),
            Duration::from_secs(300),
        )
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(resp.output.is_empty(), "encrypted results no longer ride the f32 vector");
    let out_blob = resp.result_blob.expect("typed result reference");
    assert!(out_blob >= (1u64 << 24), "ids beyond 2^24 are served exactly");
    let cts = sess.take(out_blob).unwrap();
    let h: Vec<i64> = cts.iter().map(|ct| sess.ctx.decrypt(ct, &ck)).collect();
    assert_eq!(h.len(), 4);
    // Mirror check.
    use inhibitor::fhe_circuits::InhibitorFhe;
    use inhibitor::tensor::ITensor;
    let q = ITensor::from_vec(&[2, 2], vals[0..4].to_vec());
    let k = ITensor::from_vec(&[2, 2], vals[4..8].to_vec());
    let v = ITensor::from_vec(&[2, 2], vals[8..12].to_vec());
    let want = InhibitorFhe::new(2, 1).mirror(&q, &k, &v, sess.ctx.enc.max_signed());
    assert_eq!(h, want.data);
}

#[test]
fn backpressure_surfaces_as_submit_error() {
    let mut c = Coordinator::new(RoutePolicy::PreferQuant);
    // An engine that blocks forever, with a tiny queue.
    c.add_quant_engine(
        "inhibitor",
        QTransformer::random(ModelConfig::small(Mechanism::Inhibitor, 64, 64), 1),
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 2 },
    );
    // Flood faster than a 64×64 model on one core can drain.
    let mut saw_reject = false;
    for _ in 0..200 {
        let r = c.submit(
            EnginePath::QuantInt("inhibitor".into()),
            Payload::Features(vec![0.0; 64 * 64], (64, 64)),
        );
        if r.is_err() {
            saw_reject = true;
            break;
        }
    }
    assert!(saw_reject, "queue_cap=2 must reject under flood");
}
