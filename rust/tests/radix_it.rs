//! Differential harness for the radix wide-arithmetic subsystem: over a
//! (limb count × mechanism × rewrite mode × dispatch mode) grid, every
//! attention circuit with a declared accumulator width must decrypt to
//! canonical limbs of the *exact* plaintext wide-integer mirror, with
//! executed `PBS_COUNT` / `BLIND_ROTATION_COUNT` deltas equal to the
//! plan oracles; dedicated wide-sum circuits pin those oracles against
//! `optimizer::precision::profile_radix`'s closed forms; the ϑ = 2
//! showcase pins ≥ 4-LUT packed digit groups; and legalization is a
//! structural no-op whenever the declared width already fits the native
//! message space.

use inhibitor::fhe_circuits::{CtMatrix, DotProductFhe, InhibitorFhe, InhibitorSignedFhe};
use inhibitor::optimizer::profile_radix;
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::ops::CtInt;
use inhibitor::tfhe::{
    bootstrap, set_radix_native_bits, set_wavefront_dispatch, CircuitBuilder, CircuitPlan,
    ClientKey, FheContext, PlanRewriter, RadixConfig, RadixInfo, RewriteConfig, TfheParams,
};
use inhibitor::util::prng::Xoshiro256;
use std::sync::Mutex;

/// `PBS_COUNT` / `BLIND_ROTATION_COUNT`, the wavefront override, and the
/// radix native override are process-global and tests in this binary run
/// on parallel threads; every test serializes through this lock.
static GLOBAL_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Expected decrypted slot list of a legalized plan: each wide output is
/// the canonical limb encoding of its mirror value (the legalizer always
/// ripples at the output), narrow outputs pass through.
fn expected_slots(info: &RadixInfo, want: &[i64]) -> Vec<i64> {
    assert_eq!(info.wide_outputs.len(), want.len(), "one mirror value per original output");
    let mut slots = Vec::with_capacity(info.n_slots());
    for (&wide, &w) in info.wide_outputs.iter().zip(want) {
        if wide {
            slots.extend(info.spec.encode(w));
        } else {
            slots.push(w);
        }
    }
    slots
}

/// Execute `plan`, assert the global counter deltas equal the plan's own
/// oracles, and assert the decrypted slots are bit-identical to the
/// mirror's canonical limbs. Returns the output ciphertexts.
fn run_and_check(
    plan: &CircuitPlan,
    ctx: &FheContext,
    ck: &ClientKey,
    inputs: &[CtInt],
    want: &[i64],
    label: &str,
) -> Vec<CtInt> {
    let info = plan.radix().expect("legalization fired").clone();
    let before_pbs = bootstrap::pbs_count();
    let before_rot = bootstrap::blind_rotation_count();
    let outs = plan.execute(ctx, inputs);
    assert_eq!(
        bootstrap::pbs_count() - before_pbs,
        plan.pbs_count(),
        "{label}: PBS_COUNT delta must match the plan oracle"
    );
    assert_eq!(
        bootstrap::blind_rotation_count() - before_rot,
        plan.blind_rotation_count(),
        "{label}: BLIND_ROTATION_COUNT delta must match the plan oracle"
    );
    let slots: Vec<i64> = outs.iter().map(|c| ctx.decrypt(c, ck)).collect();
    assert_eq!(slots, expected_slots(&info, want), "{label}: canonical limbs");
    assert_eq!(info.decode_outputs(&slots), want, "{label}: recombined wide values");
    outs
}

fn encrypt_qkv(
    ctx: &FheContext,
    ck: &ClientKey,
    rng: &mut Xoshiro256,
    q: &ITensor,
    k: &ITensor,
    v: &ITensor,
) -> Vec<CtInt> {
    let mut inputs = Vec::with_capacity(q.data.len() * 3);
    for tensor in [q, k, v] {
        inputs.extend(tensor.data.iter().map(|&val| ctx.encrypt(val, ck, rng)));
    }
    inputs
}

/// The full differential grid of the tentpole: limb counts {2, 3, 4} ×
/// all three attention mechanisms × rewrites on/off (off = the
/// legalize-only pipeline `FHE_NO_REWRITE` serving runs) × both dispatch
/// modes. Every cell must be bit-identical to the wide-integer mirror
/// and match the plan's own counter oracles exactly.
#[test]
fn wide_attention_grid_is_bit_identical_to_the_mirror() {
    let _g = lock();
    let (t, d) = (2usize, 1usize);
    // (parameter set, native bits, forced limb width, declared width,
    // limb count). The 7-bit row needs no forced limb width either:
    // max_limb_bits_for(7) = 4, so a declared width of 8 takes 2 limbs.
    let seven = {
        // test_for_bits(7) picks N = 2048, one poly doubling short of the
        // σ-margin the decode-exact 6-bit tests run at; double it.
        let mut p = TfheParams::test_for_bits(7);
        p.poly_size = 4096;
        p
    };
    let grid: [(TfheParams, u32, Option<u32>, u32, usize); 3] = [
        (seven, 7, None, 8, 2),
        (TfheParams::test_multi_lut(6), 6, None, 9, 3),
        (TfheParams::test_multi_lut(6), 6, Some(2), 8, 4),
    ];
    for (params, bits, limb_bits, width, k_limbs) in grid {
        let mut rng = Xoshiro256::new(0x5AD1 + width as u64);
        let ck = ClientKey::generate(params, &mut rng);
        let ctx = FheContext::new(ck.server_key(&mut rng));
        let (min_s, max_s) = (ctx.enc.min_signed(), ctx.enc.max_signed());
        let q = ITensor::from_vec(&[t, d], vec![1, -2]);
        let kk = ITensor::from_vec(&[t, d], vec![1, 0]);
        // (mechanism label, raw wide-plan builder, mirror outputs, values).
        type PlanBuilder = Box<dyn Fn() -> CircuitPlan>;
        let mechanisms: Vec<(&str, PlanBuilder, Vec<i64>, ITensor)> = vec![
            {
                let v = ITensor::from_vec(&[t, d], vec![3, 1]);
                let head = InhibitorFhe::new(d, 1).with_accumulator_bits(width);
                let want = head.mirror(&q, &kk, &v, max_s).data;
                ("inhibitor", Box::new(move || head.plan(t, d)) as PlanBuilder, want, v)
            },
            {
                let v = ITensor::from_vec(&[t, d], vec![3, -2]);
                let head = InhibitorSignedFhe::new(d, 1).with_accumulator_bits(width);
                let want = head.mirror(&q, &kk, &v, min_s, max_s).data;
                ("signed", Box::new(move || head.plan(t, d)) as PlanBuilder, want, v)
            },
            {
                let v = ITensor::from_vec(&[t, d], vec![2, -1]);
                let head = DotProductFhe::new(d, 2).with_accumulator_bits(width);
                let want = head.mirror(&q, &kk, &v, min_s, max_s).data;
                ("dotprod", Box::new(move || head.plan(t, d)) as PlanBuilder, want, v)
            },
        ];
        for (name, build, want, v) in mechanisms {
            let inputs = encrypt_qkv(&ctx, &ck, &mut rng, &q, &kk, &v);
            let mut rcfg = RadixConfig::new(bits);
            if let Some(w) = limb_bits {
                rcfg = rcfg.with_limb_bits(w);
            }
            for cfg in [RewriteConfig::none(), RewriteConfig::for_params(&ctx.sk.params)] {
                let label =
                    format!("{name} k={k_limbs} cse={} budget={}", cfg.cse, cfg.max_multi_lut);
                // Radix legalization is correctness, not optimization: it
                // runs even under the all-passes-off config serving uses
                // for its no-rewrite CI leg.
                let (plan, _) = PlanRewriter::new(cfg).with_radix(rcfg).rewrite(build());
                let info = plan.radix().unwrap_or_else(|| panic!("{label}: no legalization"));
                assert_eq!(info.spec.limbs, k_limbs, "{label}");
                assert!(info.wide_outputs.iter().all(|&w| w), "{label}: every output is wide");
                let mut per_mode: Vec<Vec<CtInt>> = Vec::new();
                for wavefront in [false, true] {
                    set_wavefront_dispatch(Some(wavefront));
                    let outs = run_and_check(
                        &plan,
                        &ctx,
                        &ck,
                        &inputs,
                        &want,
                        &format!("{label} wavefront={wavefront}"),
                    );
                    per_mode.push(outs);
                }
                set_wavefront_dispatch(None);
                for (i, (a, b)) in per_mode[0].iter().zip(per_mode[1].iter()).enumerate() {
                    assert_eq!(
                        a.ct, b.ct,
                        "{label}: dispatch modes must be ciphertext-identical (slot {i})"
                    );
                }
            }
        }
    }
}

/// A wide `Sum` of `n` distinct bootstrap outputs: the canonical shape
/// `profile_radix` models. Declared `width` bits wide.
fn wide_sum_plan(n: usize, width: u32) -> CircuitPlan {
    let mut b = CircuitBuilder::new();
    let ins = b.inputs(n);
    let terms: Vec<_> = ins
        .iter()
        .enumerate()
        .map(|(i, &x)| {
            let l = b.lut(move |v| v + i as i64);
            b.pbs(x, l)
        })
        .collect();
    let s = b.sum(&terms);
    b.declare_width(s, width);
    b.output(s);
    b.build()
}

/// `profile_radix`'s closed forms must equal the legalized plan's own
/// counter oracles at every grid point — the pass and the profile are
/// two derivations of the same arithmetic.
#[test]
fn wide_sum_counters_match_profile_radix_closed_forms() {
    for &(native, limb_bits, width) in &[(8u32, 5u32, 10u32), (6, 3, 9), (6, 2, 8)] {
        let rcfg = RadixConfig::new(native).with_limb_bits(limb_bits);
        let spec = rcfg.spec_for(width).expect("declared width exceeds native");
        for n in [1usize, 2, 3, 7] {
            for budget in [1usize, 2, 4] {
                let profile = profile_radix(n, spec, budget);
                let (plan, stats) =
                    PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: budget })
                        .with_radix(rcfg)
                        .rewrite(wide_sum_plan(n, width));
                let label = format!("native={native} w={limb_bits} n={n} budget={budget}");
                assert_eq!(plan.radix().unwrap().spec, spec, "{label}");
                // The n front bootstraps are untouched singletons (all
                // distinct inputs); everything else is the legalization.
                assert_eq!(plan.pbs_count(), n as u64 + profile.pbs, "{label}: pbs");
                assert_eq!(
                    plan.blind_rotation_count(),
                    n as u64 + profile.blind_rotations,
                    "{label}: rotations"
                );
                assert_eq!(stats.radix_widened, n, "{label}");
                assert_eq!(stats.carry_luts, profile.carry_pbs, "{label}");
                assert_eq!(stats.carry_rotations, profile.carry_rotations, "{label}");
            }
        }
    }
}

/// One wide-sum oracle executed end to end: counter deltas equal the
/// plan oracles (and therefore the closed forms pinned above), and the
/// limbs decode to the exact plaintext fold.
#[test]
fn wide_sum_executes_to_the_exact_plaintext_fold() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x5AD2);
    let ck = ClientKey::generate(TfheParams::test_multi_lut(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (plan, _) = PlanRewriter::new(RewriteConfig::for_params(&ctx.sk.params))
        .with_radix(RadixConfig::new(6))
        .rewrite(wide_sum_plan(3, 9));
    for xs in [[5i64, -7, 2], [-31, -30, -20], [31, 30, 29]] {
        let want: i64 = xs.iter().enumerate().map(|(i, &x)| x + i as i64).sum();
        let inputs: Vec<CtInt> = xs.iter().map(|&x| ctx.encrypt(x, &ck, &mut rng)).collect();
        run_and_check(&plan, &ctx, &ck, &inputs, &[want], &format!("wide sum {xs:?}"));
    }
}

/// The ϑ = 2 showcase of the issue: 2-bit limbs over an 8-bit native
/// space give span-4 digit extractions — each decomposed source of a
/// real mechanism circuit must pack into one ≥ 4-LUT blind rotation.
#[test]
fn packed_digit_groups_reach_four_luts_at_theta2() {
    let (t, d) = (2usize, 1usize);
    let head = InhibitorSignedFhe::new(d, 1).with_accumulator_bits(10);
    let (plan, _) = PlanRewriter::new(RewriteConfig { cse: true, max_multi_lut: 4 })
        .with_radix(RadixConfig::new(8).with_limb_bits(2))
        .rewrite(head.plan(t, d));
    let info = plan.radix().expect("legalization fired");
    assert_eq!((info.spec.limb_bits, info.spec.limbs, info.spec.span()), (2, 5, 4));
    let sizes = plan.multi_group_sizes();
    let big = sizes.iter().filter(|&&g| g >= 4).count();
    assert!(big >= 1, "at least one packed ϑ = 2 digit group, got {sizes:?}");
    assert_eq!(
        big, info.widened,
        "every decomposed source packs its span-4 digit group, got {sizes:?}"
    );
}

/// When the declared width already fits the native message space the
/// pass must leave the plan untouched — same structural hash, no radix
/// record, widths preserved for a later, narrower set.
#[test]
fn legalization_is_a_noop_when_the_width_fits_native() {
    let (t, d) = (2usize, 1usize);
    let head = InhibitorSignedFhe::new(d, 1).with_accumulator_bits(10);
    let raw = head.plan(t, d);
    let before = raw.structural_hash();
    let (out, stats) = PlanRewriter::new(RewriteConfig::none())
        .with_radix(RadixConfig::new(10))
        .rewrite(raw);
    assert_eq!(out.structural_hash(), before, "no-op legalization keeps the DAG");
    assert!(out.radix().is_none());
    assert_eq!(stats.radix_widened, 0);
    assert_eq!(out.declared_widths().len(), t * d, "declarations survive for narrower sets");
}

/// The production head path under a forced native width (the
/// `FHE_RADIX_NATIVE_BITS` CI leg's mechanism): `plan_for`/`forward`
/// legalize through `RadixConfig::for_params`, the output matrix widens
/// to `[T, d·limbs]`, and the limbs decode to the wide mirror.
#[test]
fn forced_native_override_legalizes_through_the_head_path() {
    let _g = lock();
    let mut rng = Xoshiro256::new(0x5AD3);
    let ck = ClientKey::generate(TfheParams::test_multi_lut(6), &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let (t, d) = (2usize, 1usize);
    let head = InhibitorSignedFhe::new(d, 1).with_accumulator_bits(8);
    set_radix_native_bits(Some(5));
    let plan = head.plan_for(&ctx, t, d);
    let info = plan.radix().expect("forced native must trigger legalization").clone();
    // max_limb_bits_for(5) = 2, so a declared width of 8 takes 4 limbs.
    assert_eq!(
        (info.spec.limb_bits, info.spec.limbs, info.spec.native_bits),
        (2, 4, 5),
        "forced-native spec"
    );
    let q = ITensor::from_vec(&[t, d], vec![1, -2]);
    let kk = ITensor::from_vec(&[t, d], vec![1, 0]);
    let v = ITensor::from_vec(&[t, d], vec![3, -2]);
    let cq = CtMatrix::encrypt(&q, &ctx, &ck, &mut rng);
    let ckk = CtMatrix::encrypt(&kk, &ctx, &ck, &mut rng);
    let cv = CtMatrix::encrypt(&v, &ctx, &ck, &mut rng);
    let h = head.forward(&ctx, &cq, &ckk, &cv);
    set_radix_native_bits(None);
    let limbs = info.spec.limbs;
    assert_eq!((h.rows, h.cols), (t, d * limbs), "wide output matrix layout");
    let want = head.mirror(&q, &kk, &v, ctx.enc.min_signed(), ctx.enc.max_signed());
    for i in 0..t {
        for e in 0..d {
            let slots: Vec<i64> = (0..limbs)
                .map(|l| ctx.decrypt(&h.data[i * d * limbs + e * limbs + l], &ck))
                .collect();
            assert_eq!(
                slots,
                info.spec.encode(want.data[i * d + e]),
                "canonical limbs of output ({i}, {e})"
            );
        }
    }
}
