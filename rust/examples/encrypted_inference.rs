//! Encrypted inference through the full coordinator (the paper's
//! motivating scenario): a client creates a session, uploads encrypted
//! Q/K/V, the server runs Inhibitor attention under TFHE without ever
//! seeing the data, and the client decrypts the result.
//!
//!   cargo run --release --example encrypted_inference [-- --mechanism dotprod]

use inhibitor::coordinator::{BatchPolicy, Coordinator, EnginePath, Payload, RoutePolicy};
use inhibitor::fhe_circuits::InhibitorFhe;
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::{bootstrap, ClientKey, FheContext, TfheParams};
use inhibitor::util::prng::Xoshiro256;
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let mechanism = if args.iter().any(|a| a == "dotprod") { "dotprod" } else { "inhibitor" };
    let (seq, dim) = (2usize, 2usize); // paper's encrypted setting

    // ---- client side: keys ----
    let mut rng = Xoshiro256::new(99);
    let params = TfheParams::test_for_bits(if mechanism == "dotprod" { 6 } else { 5 });
    println!("client: generating keys (n={}, N={}, p={} bits)", params.lwe_dim, params.poly_size, params.message_bits);
    let ck = ClientKey::generate(params, &mut rng);
    let server_ctx = FheContext::new(ck.server_key(&mut rng)); // evaluation key → server

    // ---- server side: coordinator with an FHE engine for this session ----
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    let session = coord.keymgr.create_session(server_ctx);
    coord
        .add_fhe_engine(session, mechanism, seq, dim, BatchPolicy::default())
        .expect("register fhe engine");

    // ---- client: encrypt Q/K/V and submit ----
    let q = ITensor::from_vec(&[seq, dim], vec![1, -2, 0, 2]);
    let k = ITensor::from_vec(&[seq, dim], vec![1, -1, -2, 0]);
    let v = ITensor::from_vec(&[seq, dim], vec![3, 1, 2, 0]);
    let sess = coord.keymgr.session(session).unwrap();
    let mut bundle = Vec::new();
    for m in [&q, &k, &v] {
        for &val in &m.data {
            bundle.push(sess.ctx.encrypt(val, &ck, &mut rng));
        }
    }
    let blob = sess.register(bundle);
    println!("client: uploaded {} ciphertexts as bundle {blob}", 3 * seq * dim);

    println!("server: PBS engine running {} worker thread(s)", sess.ctx.threads());
    bootstrap::reset_pbs_count();
    let t0 = Instant::now();
    let resp = coord
        .infer_blocking(
            EnginePath::Encrypted { session, mechanism: mechanism.into() },
            Payload::CiphertextRef(blob),
            Duration::from_secs(600),
        )
        .expect("inference");
    assert!(resp.error.is_none(), "{:?}", resp.error);
    // Typed result reference: the blob id travels in its own response
    // field, never encoded into the f32 output vector.
    let out_blob = resp.result_blob.expect("typed result reference");
    println!(
        "server: {} PBS in {:.3}s (engine={})",
        bootstrap::pbs_count(),
        t0.elapsed().as_secs_f64(),
        resp.engine
    );

    // ---- client: fetch + decrypt ----
    let cts = sess.take(out_blob).expect("result bundle");
    let h: Vec<i64> = cts.iter().map(|c| sess.ctx.decrypt(c, &ck)).collect();
    println!("client: decrypted H = {h:?}");
    if mechanism == "inhibitor" {
        let want = InhibitorFhe::new(dim, 1).mirror(&q, &k, &v, sess.ctx.enc.max_signed());
        assert_eq!(h, want.data, "must match the plaintext mirror");
        println!("matches plaintext mirror ✓");
    }
}
