//! E2E serving driver (E6 / headline validation): start the full TCP
//! server with quant + PJRT engines, fire batched concurrent requests
//! from client threads, and report latency/throughput per engine.
//!
//!   cargo run --release --example serving_benchmark [-- --requests 400 --clients 8]

use inhibitor::attention::Mechanism;
use inhibitor::coordinator::{BatchPolicy, Coordinator, RoutePolicy};
use inhibitor::model::{ModelConfig, QTransformer};
use inhibitor::server::Client;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn flag(args: &[String], name: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_requests = flag(&args, "--requests", 400);
    let n_clients = flag(&args, "--clients", 8);

    // ---- bring up the server on an ephemeral port ----
    let mut coord = Coordinator::new(RoutePolicy::PreferQuant);
    for m in [Mechanism::DotProduct, Mechanism::Inhibitor] {
        // Match the AOT model contract (seq 16, 2 input features) so the
        // same request payload exercises the quant and PJRT engines.
        let mut cfg = ModelConfig::small(m, 16, 32);
        cfg.in_features = 2;
        coord.add_quant_engine(
            m.name(),
            QTransformer::random(cfg, 11),
            BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2), queue_cap: 8192 },
        );
    }
    let have_artifacts = cfg!(feature = "xla")
        && std::path::Path::new("artifacts/manifest.json").exists();
    #[cfg(feature = "xla")]
    if have_artifacts {
        coord.add_pjrt_model(
            "artifacts".into(),
            "model_inhibitor",
            BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2), queue_cap: 8192 },
        );
    }
    let coord = Arc::new(coord);
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = {
        let c = Arc::clone(&coord);
        std::thread::spawn(move || {
            inhibitor::server::serve(c, "127.0.0.1:0", move |a| {
                let _ = addr_tx.send(a);
            })
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).expect("server address");
    println!("server bound at {addr}");

    // ---- engines to benchmark over the wire ----
    let mut plans: Vec<(&str, &str)> =
        vec![("quant", "inhibitor"), ("quant", "dotprod")];
    if have_artifacts {
        plans.push(("pjrt", "model_inhibitor"));
    }

    for (engine, target) in plans {
        let t0 = Instant::now();
        let mut handles = Vec::new();
        let per_client = n_requests / n_clients;
        for c in 0..n_clients {
            let addr = addr.to_string();
            let engine = engine.to_string();
            let target = target.to_string();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let mut latencies = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let x = ((c * per_client + i) as f32 * 0.01).sin();
                    let feats = vec![x; 16 * 2];
                    let t = Instant::now();
                    let r = client
                        .infer(&engine, &target, feats, 16, 2)
                        .expect("io")
                        .expect("inference");
                    latencies.push(t.elapsed().as_secs_f64());
                    let _ = r;
                }
                latencies
            }));
        }
        let mut all: Vec<f64> = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        let p = |q: f64| all[((all.len() as f64 * q) as usize).min(all.len() - 1)];
        println!(
            "{engine:>5}/{target:<16} {:>5} reqs {:>2} clients: {:>8.1} req/s  \
             mean {:>7.2}ms  p50 {:>7.2}ms  p99 {:>7.2}ms",
            all.len(),
            n_clients,
            all.len() as f64 / wall,
            mean * 1e3,
            p(0.5) * 1e3,
            p(0.99) * 1e3,
        );
    }
    println!("\nserver metrics: {}", coord.metrics().summary());

    // ---- shut down ----
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let _ = c.shutdown();
    let _ = server.join();
}
