//! TFHE parameter optimization walkthrough (E2 / Table 2): profiles both
//! attention circuits at several sequence lengths, runs the Bergerat-style
//! macro/micro search, and prints the selected parameters with estimated
//! per-circuit cost — the reproduction of the paper's Table 2.
//!
//!   cargo run --release --example params_search

use inhibitor::attention::Mechanism;
use inhibitor::optimizer::{optimize, profile, SearchConfig};

fn main() {
    let cfg = SearchConfig::default();
    println!("security λ={} bits, per-PBS failure target 2^{:.1}", cfg.security, cfg.p_fail.log2());
    println!(
        "\n{:>4} {:<12} {:>4} {:>5} {:>6} | {:>7} {:>8} {:>6} {:>9} | {:>10} {:>12}",
        "T", "mechanism", "int", "uint", "#PBS", "lweDim", "baseLog", "level", "polySize", "msg bits", "rel. cost"
    );
    let mut base_cost = None;
    for t in [2usize, 4, 8, 16] {
        for mech in [Mechanism::Inhibitor, Mechanism::DotProduct] {
            let prof = profile(mech, t, 2, 3);
            match optimize(&prof, cfg) {
                Some(opt) => {
                    let base = *base_cost.get_or_insert(opt.circuit_flops);
                    println!(
                        "{:>4} {:<12} {:>4} {:>5} {:>6} | {:>7} {:>8} {:>6} {:>9} | {:>10} {:>12.1}",
                        t,
                        mech.name(),
                        prof.int_bits,
                        prof.uint_bits,
                        prof.pbs_count,
                        opt.params.lwe_dim,
                        opt.params.pbs_decomp.base_log,
                        opt.params.pbs_decomp.level,
                        opt.params.poly_size,
                        opt.params.message_bits,
                        opt.circuit_flops / base,
                    );
                }
                None => println!("{t:>4} {:<12}  — no feasible parameters", mech.name()),
            }
        }
    }
    println!(
        "\npaper Table 2 (for shape comparison):\n\
         T=2:  inh lweDim 816 blog 23 lvl 1 poly 2048 int 5 uint 4 | dot 817/23/1/2048 int 6 uint 7\n\
         T=4:  inh 875/22/1/4096 int 6 uint 5 | dot 834/23/1/2048 int 7 uint 7\n\
         T=8:  inh 795/22/1/4096 int 5 uint 5 | dot 792/22/1/4096 int 7 uint 8\n\
         T=16: inh 883/22/1/4096 int 6 uint 6 | dot 794/15/2/4096 int 8 uint 8"
    );
}
