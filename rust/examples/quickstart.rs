//! Quickstart: the three execution paths of the stack in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! 1. loads the AOT float artifact (L2/L1 lowered to HLO) and runs it on
//!    PJRT,
//! 2. runs the same-shaped quantized integer model,
//! 3. runs one tiny encrypted inhibitor attention and decrypts it.

use inhibitor::attention::Mechanism;
use inhibitor::fhe_circuits::{CtMatrix, InhibitorFhe};
use inhibitor::model::{ModelConfig, ModelInput, QTransformer};
use inhibitor::tensor::ITensor;
use inhibitor::tfhe::{ClientKey, FheContext, TfheParams};
use inhibitor::util::prng::Xoshiro256;

/// PJRT float path (requires `make artifacts` and the `xla` feature).
#[cfg(feature = "xla")]
fn pjrt_demo() {
    let run = || -> Result<(), String> {
        let mut reg =
            inhibitor::runtime::Registry::open("artifacts").map_err(|e| format!("{e:#}"))?;
        let engine = reg.attention_engine("inhibitor", 32).map_err(|e| format!("{e:#}"))?;
        let n = 32 * 64;
        let q = vec![0.25f32; n];
        let out = engine.run_f32(&[q.clone(), q.clone(), q]).map_err(|e| format!("{e:#}"))?;
        println!(
            "[pjrt]  inhibitor attention T=32 d=64 -> {} outputs, H[0]={:.4}",
            out.len(),
            out[0]
        );
        Ok(())
    };
    if let Err(e) = run() {
        println!("[pjrt]  skipped ({e}) — run `make artifacts`");
    }
}

#[cfg(not(feature = "xla"))]
fn pjrt_demo() {
    println!("[pjrt]  skipped (built without the `xla` feature)");
}

fn main() {
    // --- 1. PJRT float path ----------------------------------------------
    pjrt_demo();

    // --- 2. quantized integer path ---------------------------------------
    let cfg = ModelConfig::small(Mechanism::Inhibitor, 16, 32);
    let model = QTransformer::random(cfg, 7);
    let mut rng = Xoshiro256::new(1);
    let x = ITensor::random(&[16, 32], -100, 100, &mut rng);
    let y = model.forward(&ModelInput::Features(x));
    println!("[quant] int16 transformer forward -> {:?} = {:?}", y.dims(), y.data);

    // --- 3. encrypted path ------------------------------------------------
    // 5-bit messages: enough headroom for the T=2 circuit's intermediates
    // (the precision analysis in optimizer::precision is what sizes this).
    let params = TfheParams::test_for_bits(5);
    let ck = ClientKey::generate(params, &mut rng);
    let ctx = FheContext::new(ck.server_key(&mut rng));
    let q = ITensor::from_vec(&[2, 2], vec![1, -1, 0, 2]);
    let k = ITensor::from_vec(&[2, 2], vec![1, -1, -2, 1]);
    let v = ITensor::from_vec(&[2, 2], vec![2, 1, 3, 0]);
    let h = InhibitorFhe::new(2, 1).forward(
        &ctx,
        &CtMatrix::encrypt(&q, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&k, &ctx, &ck, &mut rng),
        &CtMatrix::encrypt(&v, &ctx, &ck, &mut rng),
    );
    let dec = h.decrypt(&ctx, &ck);
    let want = InhibitorFhe::new(2, 1).mirror(&q, &k, &v, ctx.enc.max_signed());
    println!("[fhe]   encrypted inhibitor H = {:?} (plaintext mirror {:?})", dec.data, want.data);
    assert_eq!(dec, want, "encrypted result must match the plaintext mirror");
    println!("quickstart ok");
}
